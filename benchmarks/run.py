# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV. Set BENCH_FULL=1 for the full-budget (paper-scale) search runs.
import sys
import time


def main() -> None:
    from . import paper_figs, bench_kernels, bench_search, roofline_report

    benches = [
        bench_search.scoring_throughput,
        bench_search.e2e_speedup,
        bench_search.search_wall,
        paper_figs.fig4_motivation,
        paper_figs.fig10_overall,
        paper_figs.fig11_vs_overlapim,
        paper_figs.fig12_perlayer,
        paper_figs.fig13_memcap,
        paper_figs.fig14_runtime,
        paper_figs.fig15_search_methods,
        paper_figs.fig16_reram,
        paper_figs.fig17_bert,
        paper_figs.sec4f_dataspace_generation,
        bench_kernels.kernels,
    ]
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for bench in benches:
        try:
            for row in bench():
                print(row, flush=True)
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"{bench.__name__},0.000,ERROR:{e!r}", flush=True)
    # roofline rows come from the dry-run artifacts (if present)
    try:
        for row in roofline_report.roofline_rows("16x16"):
            print(row, flush=True)
    except Exception as e:
        print(f"roofline_report,0.000,ERROR:{e!r}", flush=True)
    print(f"# total_wall_s={time.time() - t0:.1f} failures={failures}",
          flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
