"""Benchmark driver.

Two entry points::

    python benchmarks/run.py [bench]      # paper-figure + perf CSV suite
    python benchmarks/run.py dse [...]    # architecture DSE sweep

Both also work as ``python -m benchmarks.run`` with ``PYTHONPATH=src``;
run as a plain script the repo root and ``src/`` are bootstrapped onto
``sys.path``. The ``bench`` suite prints ``name,us_per_call,derived`` CSV
(set ``BENCH_FULL=1`` for paper-scale budgets); perf-relevant rows are
mirrored into ``BENCH_search.json``. The ``dse`` subcommand co-searches
PIM architectures x overlap mappings (``repro.dse``), prints the Pareto
frontier and writes a resumable JSONL journal — re-running a finished
sweep performs zero new mapping searches.
"""
import argparse
import dataclasses
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def bench_main() -> None:
    # one function per paper table/figure
    from benchmarks import (bench_kernels, bench_search, paper_figs,
                            roofline_report)

    benches = [
        bench_search.scoring_throughput,
        bench_search.e2e_speedup,
        bench_search.search_wall,
        bench_search.objective_frontier,
        paper_figs.fig4_motivation,
        paper_figs.fig10_overall,
        paper_figs.fig11_vs_overlapim,
        paper_figs.fig12_perlayer,
        paper_figs.fig13_memcap,
        paper_figs.fig14_runtime,
        paper_figs.fig15_search_methods,
        paper_figs.fig16_reram,
        paper_figs.fig17_bert,
        paper_figs.sec4f_dataspace_generation,
        bench_kernels.kernels,
    ]
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for bench in benches:
        try:
            for row in bench():
                print(row, flush=True)
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"{bench.__name__},0.000,ERROR:{e!r}", flush=True)
    # roofline rows come from the dry-run artifacts (if present)
    try:
        for row in roofline_report.roofline_rows("16x16"):
            print(row, flush=True)
    except Exception as e:
        print(f"roofline_report,0.000,ERROR:{e!r}", flush=True)
    print(f"# total_wall_s={time.time() - t0:.1f} failures={failures}",
          flush=True)
    if failures:
        sys.exit(1)


def _dse_parser() -> argparse.ArgumentParser:
    from repro.dse import EXPLORERS, SPACES
    from repro.core.search import MODES, OBJECTIVES, STRATEGIES

    p = argparse.ArgumentParser(
        prog="run.py dse",
        description="Co-search PIM architectures x overlap mappings.")
    p.add_argument("--network", default="resnet18",
                   help="network name, or 'all' for "
                        "resnet18/vgg16/bert_encoder x all modes")
    p.add_argument("--family", default="dram_pim", choices=sorted(SPACES))
    p.add_argument("--mode", default="transform", choices=MODES)
    p.add_argument("--strategy", default="forward", choices=STRATEGIES)
    p.add_argument("--objective", default="latency", choices=OBJECTIVES,
                   help="mapping-search objective (energy/edp/blend make "
                        "the sweep energy-aware)")
    p.add_argument("--blend-alpha", type=float, default=0.5,
                   help="energy weight of the 'blend' objective")
    p.add_argument("--explorer", default="evolve", choices=EXPLORERS)
    p.add_argument("--budget", type=int, default=64,
                   help="design points to propose (journal hits included)")
    p.add_argument("--seed", type=int, default=1)
    p.add_argument("--candidates", type=int, default=8,
                   help="mapping candidates per layer per point")
    p.add_argument("--max-steps", type=int, default=2048)
    p.add_argument("--workers", type=int, default=0,
                   help="process-pool size (0 = serial, shared engine)")
    p.add_argument("--journal", default=None,
                   help="JSONL journal path (default: "
                        "dse_runs/<family>_<network>_<mode>.jsonl)")
    return p


def dse_main(argv) -> None:
    args = _dse_parser().parse_args(argv)
    from benchmarks import record
    from repro.dse import (DSEConfig, best_arch_table, frontier_table,
                           record_edp, run_dse, summarize, sweep_networks)

    # one journal-naming scheme for both branches; a literal --journal
    # path has no {placeholders} and formats to itself. Non-latency
    # objectives journal separately (their records carry different
    # chosen mappings and objective_value columns); blend is further
    # tagged with its alpha so differently-weighted sweeps never share a
    # journal file or a BENCH entry.
    if args.objective == "latency":
        obj_tag = ""
    elif args.objective == "blend":
        obj_tag = f"blend{args.blend_alpha:g}"
    else:
        obj_tag = args.objective
    journal_template = args.journal or os.path.join(
        "dse_runs", args.family + "_{network}_{mode}"
        + (f"_{obj_tag}" if obj_tag else "") + ".jsonl")

    def sweep_summary(res) -> dict:
        best = res.best_within_area() or res.baseline
        best_edp = res.best_by("edp_ns_pj") or res.baseline
        return {
            "explorer": res.config.explorer,
            "objective": res.config.objective,
            "blend_alpha": res.config.blend_alpha,
            "budget": res.config.budget,
            "evaluated": res.stats["evaluated"],
            "from_journal": res.stats["from_journal"],
            "frontier": res.stats["frontier"],
            "wall_s": round(res.stats["wall_s"], 2),
            "baseline_arch": res.baseline["arch_name"],
            "baseline_total_ns": res.baseline["total_ns"],
            "baseline_energy_pj": res.baseline["energy_pj"],
            "baseline_edp_ns_pj": record_edp(res.baseline),
            "best_iso_area_arch": best["arch_name"],
            "best_iso_area_total_ns": best["total_ns"],
            "best_iso_area_point": best["point"],
            "best_edp_arch": best_edp["arch_name"],
            "best_edp_ns_pj": record_edp(best_edp),
            "best_edp_total_ns": best_edp["total_ns"],
            "best_edp_energy_pj": best_edp["energy_pj"],
            # True iff some frontier point beats the latency-only search
            # on the default arch (the baseline) on EDP
            "frontier_dominates_baseline_on_edp": any(
                p.objectives[0] * p.objectives[1] < record_edp(res.baseline)
                for p in res.frontier.points),
            # the energy-aware frontier itself (latency/energy/area all
            # minimized), so BENCH_search.json records the trade-off
            "frontier_points": [
                {"arch_name": (p.payload or {}).get("arch_name", p.key),
                 "total_ns": p.objectives[0],
                 "energy_pj": p.objectives[1],
                 "area_mm2": p.objectives[2],
                 "move_energy_pj": (p.payload or {}).get("move_energy_pj"),
                 "edp_ns_pj": p.objectives[0] * p.objectives[1]}
                for p in res.frontier.points],
        }

    base = DSEConfig(
        family=args.family, mode=args.mode, strategy=args.strategy,
        explorer=args.explorer, budget=args.budget, seed=args.seed,
        n_candidates=args.candidates, max_steps=args.max_steps,
        objective=args.objective, blend_alpha=args.blend_alpha,
        workers=args.workers)

    # dse-journal key: objective-suffixed for non-latency sweeps so the
    # pre-energy entries keep tracking the latency trajectory
    def dse_key(net, mode) -> str:
        return f"{args.family}/{net}/{mode}" + (
            f"/{obj_tag}" if obj_tag else "")

    if args.network == "all":
        base = dataclasses.replace(base, journal_path=journal_template)
        results = sweep_networks(base)
        for (net, mode), res in sorted(results.items()):
            print(f"== {net} / {mode} ==")
            print(summarize(res))
            print(frontier_table(res.frontier))
            print()
            record.update_dse(dse_key(net, mode), sweep_summary(res))
        print(best_arch_table(results))
        return

    cfg = dataclasses.replace(
        base, network=args.network,
        journal_path=journal_template.format(network=args.network,
                                             mode=args.mode))
    res = run_dse(cfg)
    print(summarize(res))
    print(frontier_table(res.frontier))
    print(f"dse: journal={cfg.journal_path} entries={_journal_len(cfg)}")
    record.update_dse(dse_key(args.network, args.mode),
                      sweep_summary(res))


def _journal_len(cfg) -> int:
    from repro.dse import RunJournal
    return len(RunJournal(cfg.journal_path))


def main() -> None:
    argv = sys.argv[1:]
    if argv and argv[0] == "dse":
        dse_main(argv[1:])
    elif not argv or argv[0] == "bench":
        bench_main()
    else:
        print(f"unknown subcommand {argv[0]!r}; use 'bench' or 'dse'",
              file=sys.stderr)
        sys.exit(2)


if __name__ == '__main__':
    main()
