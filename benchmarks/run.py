"""Benchmark driver.

Entry points::

    python benchmarks/run.py [bench]            # paper-figure CSV suite
    python benchmarks/run.py dse [...]          # architecture DSE sweep
    python benchmarks/run.py serve-dse [...]    # one mapping-service request
    python benchmarks/run.py serve-http [...]   # the same service over HTTP
    python benchmarks/run.py dse-worker [...]   # join a distributed sweep
    python benchmarks/run.py dse-coordinator [...]  # drive one
    python benchmarks/run.py obs-report [...]   # render saved telemetry
    python benchmarks/run.py obs-profile [...]  # analyze a span trace

All also work as ``python -m benchmarks.run`` with ``PYTHONPATH=src``;
run as a plain script the repo root and ``src/`` are bootstrapped onto
``sys.path``. The ``bench`` suite prints ``name,us_per_call,derived`` CSV
(set ``BENCH_FULL=1`` for paper-scale budgets); perf-relevant rows are
mirrored into ``BENCH_search.json``. The ``dse`` subcommand co-searches
PIM architectures x overlap mappings (``repro.dse``), prints the Pareto
frontier and writes a resumable JSONL journal — re-running a finished
sweep performs zero new mapping searches. ``dse --distributed N`` runs
the same sweep through the shared-dir work-stealing subsystem
(``repro.dse.distrib``) with N local worker processes; the
``dse-worker``/``dse-coordinator`` pair does the same across real
processes or machines sharing one directory (DESIGN.md Section 10).
``serve-dse`` answers one deployment request through the mapping
service (``repro.serve.MappingService``, DESIGN.md Section 11) — an
HTTP-less local client whose repeat invocations are served from the
service journal with zero new mapping searches. ``serve-http`` binds
the same service to a listening socket (``repro.serve.transport``,
DESIGN.md Section 13): POST /v1/mapping, GET /v1/metrics (Prometheus
text), GET /v1/healthz — with request coalescing, a shared
cross-request overlap engine, and 429 load-shed past ``--max-pending``
waiting requests. Every subcommand takes
``--trace-out PATH`` / ``--metrics-out PATH`` (``repro.obs``): spans go
to a JSONL trace, the end-of-run metrics snapshot to a JSON file that
``obs-report`` renders as cache hit rates, latency percentiles and
fleet/service counters (``--prometheus`` for scrape-format text).
``obs-profile`` analyzes the span JSONL a ``--trace-out`` run wrote:
self/total-time attribution per span name, the critical path, and
optional Chrome trace-event JSON (``--chrome-out``, loadable in
Perfetto / chrome://tracing) and folded-stack flamegraph text
(``--folded-out``).
"""
import argparse
import dataclasses
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _obs_flags(p: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Attach the shared observability flags (``repro.obs``) to a
    subcommand parser. Giving either path flag turns telemetry on for
    the run; with neither, the process keeps the zero-overhead no-op
    default."""
    g = p.add_argument_group("observability (repro.obs)")
    g.add_argument("--trace-out", default=None, metavar="PATH",
                   help="write tracing spans as JSONL to PATH "
                        "(enables telemetry for this run)")
    g.add_argument("--metrics-out", default=None, metavar="PATH",
                   help="write the end-of-run metrics snapshot as JSON "
                        "to PATH (enables telemetry; defaults to "
                        "dse_runs/obs_metrics.json whenever telemetry "
                        "is on) — render it with 'run.py obs-report'")
    g.add_argument("--obs-sample", type=int, default=1, metavar="N",
                   help="keep every Nth span per span name "
                        "(deterministic stride, never RNG; metrics "
                        "counters are always exact)")
    return p


DEFAULT_METRICS_OUT = os.path.join("dse_runs", "obs_metrics.json")


def _setup_obs(args):
    """Enable process-wide telemetry per the CLI flags; returns a
    finalizer that writes the registry snapshot to ``--metrics-out``
    and turns telemetry back off (pass ``extra=...`` to merge
    additional top-level keys — e.g. the serve flight recorder — into
    the saved snapshot). With no obs flags the finalizer is a no-op
    and telemetry stays disabled."""
    import json
    trace_out = getattr(args, "trace_out", None)
    metrics_out = getattr(args, "metrics_out", None)
    if not trace_out and not metrics_out:
        return lambda extra=None: None
    from repro import obs
    metrics_out = metrics_out or DEFAULT_METRICS_OUT
    obs.enable(trace_path=trace_out,
               sample_every=max(1, getattr(args, "obs_sample", 1)))

    def finish(extra=None) -> None:
        reg = obs.registry()
        snap = reg.snapshot() if reg is not None else {}
        if extra:
            snap.update(extra)
        obs.disable()          # flushes + closes the trace sink
        d = os.path.dirname(metrics_out)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(metrics_out, "w", encoding="utf-8") as fh:
            json.dump(snap, fh, sort_keys=True)
            fh.write("\n")
        msg = f"obs: metrics -> {metrics_out}"
        if trace_out:
            msg += f" trace -> {trace_out}"
        print(msg)

    return finish


def _print_fleet(stats) -> None:
    """One-line fleet-health summary after a distributed sweep (the
    worker counters used to die with the worker processes)."""
    fleet = (stats or {}).get("fleet")
    if not fleet:
        return

    def fmt(v):
        return f"{v:.4g}" if isinstance(v, float) else str(v)

    print("dse: fleet " + " ".join(f"{k}={fmt(v)}"
                                   for k, v in sorted(fleet.items())))


def obs_report_main(argv) -> None:
    """Render a saved metrics snapshot (``--metrics-out``) as the
    human-readable observability report, or as Prometheus text
    exposition for scraping."""
    import json
    from repro import obs

    p = argparse.ArgumentParser(
        prog="run.py obs-report",
        description="Render a repro.obs metrics snapshot (cache hit "
                    "rates, latency percentiles, fleet/service "
                    "counters) written by --metrics-out.")
    p.add_argument("--metrics", default=DEFAULT_METRICS_OUT,
                   metavar="PATH", help="snapshot JSON to render "
                   "(default: %(default)s)")
    p.add_argument("--prometheus", action="store_true",
                   help="emit Prometheus text exposition instead of "
                        "the text report")
    args = p.parse_args(argv)
    try:
        with open(args.metrics, "r", encoding="utf-8") as fh:
            snap = json.load(fh)
    except FileNotFoundError:
        print(f"obs-report: no snapshot at {args.metrics} — run a "
              "subcommand with --metrics-out/--trace-out first",
              file=sys.stderr)
        sys.exit(2)
    except ValueError as e:
        # empty or truncated snapshot (e.g. a crashed run) — report,
        # don't traceback
        print(f"obs-report: {args.metrics} is not a metrics snapshot "
              f"({e})", file=sys.stderr)
        sys.exit(2)
    if not isinstance(snap, dict):
        print(f"obs-report: {args.metrics} is not a metrics snapshot "
              "(expected a JSON object)", file=sys.stderr)
        sys.exit(2)
    render = obs.render_prometheus if args.prometheus else obs.render_report
    sys.stdout.write(render(snap))


def obs_profile_main(argv) -> None:
    """Analyze a span JSONL trace (``--trace-out``): per-span-name
    self/total-time attribution, the critical path, and optional
    Chrome trace-event / folded-flamegraph exports."""
    from repro.obs import profile as obs_profile

    p = argparse.ArgumentParser(
        prog="run.py obs-profile",
        description="Trace analytics for a repro.obs span JSONL: "
                    "where did the run's wall clock go (self-time "
                    "attribution, critical path), plus Chrome "
                    "trace-event JSON (Perfetto / chrome://tracing) "
                    "and folded-stack flamegraph exports.")
    p.add_argument("--trace", required=True, metavar="PATH",
                   help="span JSONL written by --trace-out")
    p.add_argument("--chrome-out", default=None, metavar="PATH",
                   help="write Chrome trace-event JSON to PATH")
    p.add_argument("--folded-out", default=None, metavar="PATH",
                   help="write folded stacks ('a;b;c <us>' lines, "
                        "flamegraph.pl-compatible) to PATH")
    p.add_argument("--top", type=int, default=15, metavar="N",
                   help="rows in the self-time table "
                        "(default: %(default)s)")
    args = p.parse_args(argv)
    if not os.path.exists(args.trace):
        print(f"obs-profile: no trace at {args.trace} — run a "
              "subcommand with --trace-out first", file=sys.stderr)
        sys.exit(2)
    trace = obs_profile.parse_trace(args.trace)
    sys.stdout.write(obs_profile.render_profile(trace, top=args.top))
    if args.chrome_out:
        obs_profile.write_chrome_trace(trace, args.chrome_out)
        print(f"obs-profile: chrome trace -> {args.chrome_out} "
              "(load in Perfetto or chrome://tracing)")
    if args.folded_out:
        obs_profile.write_folded(trace, args.folded_out)
        print(f"obs-profile: folded stacks -> {args.folded_out}")


def bench_main(argv=()) -> None:
    args = _obs_flags(argparse.ArgumentParser(
        prog="run.py bench",
        description="Paper-figure CSV suite.")).parse_args(argv)
    finish_obs = _setup_obs(args)
    try:
        _bench_suite()
    finally:
        finish_obs()


def _bench_suite() -> None:
    # one function per paper table/figure
    from benchmarks import (bench_kernels, bench_search, bench_serve,
                            paper_figs, roofline_report)

    benches = [
        bench_search.scoring_throughput,
        bench_search.obs_overhead,
        bench_search.e2e_speedup,
        bench_search.search_wall,
        bench_search.objective_frontier,
        bench_search.worker_scaling,
        bench_serve.serve_latency,
        paper_figs.fig4_motivation,
        paper_figs.fig10_overall,
        paper_figs.fig11_vs_overlapim,
        paper_figs.fig12_perlayer,
        paper_figs.fig13_memcap,
        paper_figs.fig14_runtime,
        paper_figs.fig15_search_methods,
        paper_figs.fig16_reram,
        paper_figs.fig17_bert,
        paper_figs.sec4f_dataspace_generation,
        bench_kernels.kernels,
    ]
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for bench in benches:
        try:
            for row in bench():
                print(row, flush=True)
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"{bench.__name__},0.000,ERROR:{e!r}", flush=True)
    # roofline rows come from the dry-run artifacts (if present)
    try:
        for row in roofline_report.roofline_rows("16x16"):
            print(row, flush=True)
    except Exception as e:
        print(f"roofline_report,0.000,ERROR:{e!r}", flush=True)
    print(f"# total_wall_s={time.time() - t0:.1f} failures={failures}",
          flush=True)
    if failures:
        sys.exit(1)


def _dse_parser() -> argparse.ArgumentParser:
    from repro.dse import EXPLORERS, SPACES
    from repro.core.search import MODES, OBJECTIVES, STRATEGIES

    p = argparse.ArgumentParser(
        prog="run.py dse",
        description="Co-search PIM architectures x overlap mappings.")
    p.add_argument("--network", default="resnet18",
                   help="network name, a zoo scenario "
                        "('<arch>[:phase][@length][xblocks]', e.g. "
                        "deepseek_moe_16b:prefill@2048 — see 'run.py "
                        "workloads'), or 'all' for "
                        "resnet18/vgg16/bert_encoder x all modes")
    p.add_argument("--family", default="dram_pim", choices=sorted(SPACES))
    p.add_argument("--mode", default="transform", choices=MODES)
    p.add_argument("--strategy", default="forward", choices=STRATEGIES)
    p.add_argument("--objective", default="latency", choices=OBJECTIVES,
                   help="mapping-search objective (energy/edp/blend make "
                        "the sweep energy-aware)")
    p.add_argument("--blend-alpha", type=float, default=0.5,
                   help="energy weight of the 'blend' objective")
    p.add_argument("--explorer", default="evolve", choices=EXPLORERS)
    p.add_argument("--budget", type=int, default=64,
                   help="design points to propose (journal hits included)")
    p.add_argument("--seed", type=int, default=1)
    p.add_argument("--candidates", type=int, default=8,
                   help="mapping candidates per layer per point")
    p.add_argument("--max-steps", type=int, default=2048)
    p.add_argument("--workers", type=int, default=0,
                   help="process-pool size (0 = serial, shared engine)")
    p.add_argument("--journal", default=None,
                   help="JSONL journal path (default: "
                        "dse_runs/<family>_<network>_<mode>.jsonl)")
    p.add_argument("--distributed", type=int, default=0, metavar="N",
                   help="run the sweep through the distributed subsystem "
                        "with N local worker processes sharing a journal "
                        "directory (repro.dse.distrib)")
    p.add_argument("--shared-dir", default=None,
                   help="shared journal directory for --distributed / "
                        "dse-coordinator (default: <journal path with "
                        ".jsonl replaced by .shared>)")
    p.add_argument("--batch-size", type=int, default=1,
                   help="design points per distributed work batch")
    p.add_argument("--lease-ttl", type=float, default=60.0,
                   help="seconds before a silent worker's batch lease "
                        "expires and peers may steal it")
    p.add_argument("--compact-journal", action="store_true",
                   help="compact the journal (drop superseded later-wins "
                        "duplicates and any truncated tail) and exit")
    p.add_argument("--frontier-out", default=None, metavar="PATH",
                   help="also write the frontier's canonical JSON to "
                        "PATH (byte-comparable across runs/workers)")
    return _obs_flags(p)


def _dse_config_from_args(args):
    """THE args -> DSEConfig mapping — every scoring-relevant CLI flag
    is wired here once, so `dse`, `dse --distributed` and
    `dse-coordinator` can never score the same sweep under silently
    different configs (the bit-identical-frontier contract)."""
    from repro.dse import DSEConfig
    return DSEConfig(
        family=args.family, network=args.network, mode=args.mode,
        strategy=args.strategy, explorer=args.explorer,
        budget=args.budget, seed=args.seed, n_candidates=args.candidates,
        max_steps=args.max_steps, objective=args.objective,
        blend_alpha=args.blend_alpha, workers=args.workers)


def _compact_journal(journal_path=None, shared_dir=None) -> None:
    from repro.dse import RunJournal, SharedDirBackend
    if shared_dir is not None:
        j, where = RunJournal(backend=SharedDirBackend(shared_dir)), \
            shared_dir
    else:
        j, where = RunJournal(journal_path), journal_path
    before, after = j.compact()
    print(f"dse: compacted {where}: {before} lines -> {after}")


def _write_frontier(res, path) -> None:
    if not path:
        return
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(res.frontier.canonical_json() + "\n")
    print(f"dse: frontier written to {path}")


def dse_main(argv) -> None:
    args = _dse_parser().parse_args(argv)
    from benchmarks import record
    from repro.dse import (best_arch_table, execute_sweep, frontier_table,
                           journal_template, network_token, objective_tag,
                           shared_dir_for, summarize, sweep_networks,
                           sweep_summary)

    # one journal-naming scheme for both branches (repro.dse.driver —
    # shared with the mapping service); a literal --journal path has no
    # {placeholders} and formats to itself
    obj_tag = objective_tag(args.objective, args.blend_alpha)
    template = args.journal or journal_template(
        args.family, args.objective, args.blend_alpha)

    base = _dse_config_from_args(args)

    # dse-journal key: objective-suffixed for non-latency sweeps so the
    # pre-energy entries keep tracking the latency trajectory
    def dse_key(net, mode) -> str:
        return f"{args.family}/{net}/{mode}" + (
            f"/{obj_tag}" if obj_tag else "")

    if args.network == "all":
        if args.distributed or args.compact_journal or args.frontier_out:
            print("--distributed/--compact-journal/--frontier-out need "
                  "a single --network, not 'all'", file=sys.stderr)
            sys.exit(2)
        base = dataclasses.replace(base, journal_path=template)
        results = sweep_networks(base)
        for (net, mode), res in sorted(results.items()):
            print(f"== {net} / {mode} ==")
            print(summarize(res))
            print(frontier_table(res.frontier))
            print()
            record.update_dse(dse_key(net, mode), sweep_summary(res))
        print(best_arch_table(results))
        return

    journal_path = template.format(network=network_token(args.network),
                                   mode=args.mode)
    shared_dir = args.shared_dir or shared_dir_for(journal_path)

    if args.compact_journal:
        if args.shared_dir or args.distributed:
            _compact_journal(shared_dir=shared_dir)
        else:
            _compact_journal(journal_path=journal_path)
        return

    cfg = dataclasses.replace(base, network=args.network,
                              journal_path=journal_path)
    finish_obs = _setup_obs(args)
    try:
        res = execute_sweep(cfg, distributed=args.distributed,
                            shared_dir=shared_dir if args.distributed
                            else None,
                            batch_size=args.batch_size,
                            lease_ttl_s=args.lease_ttl)
    finally:
        finish_obs()
    print(summarize(res))
    print(frontier_table(res.frontier))
    if args.distributed:
        print(f"dse: shared-dir={shared_dir} "
              f"workers={args.distributed} "
              f"batches={res.stats['batches']}")
        _print_fleet(res.stats)
    else:
        print(f"dse: journal={cfg.journal_path} entries={_journal_len(cfg)}")
    _write_frontier(res, args.frontier_out)
    record.update_dse(dse_key(args.network, args.mode),
                      sweep_summary(res))


def _journal_len(cfg) -> int:
    from repro.dse import RunJournal
    return len(RunJournal(cfg.journal_path))


def dse_worker_main(argv) -> None:
    """Join a distributed sweep knowing nothing but the shared dir."""
    from repro.dse.distrib import WorkerConfig, worker_loop

    p = argparse.ArgumentParser(
        prog="run.py dse-worker",
        description="Evaluate batches of a distributed DSE sweep until "
                    "the coordinator posts STOP. Point any number of "
                    "these (any machine) at one shared directory.")
    p.add_argument("--shared-dir", required=True)
    p.add_argument("--worker-id", default=None,
                   help="stable identity (default: pid + random)")
    p.add_argument("--lease-ttl", type=float, default=60.0)
    p.add_argument("--poll", type=float, default=0.05)
    p.add_argument("--max-idle", type=float, default=900.0,
                   help="exit after this many idle seconds even without "
                        "a STOP (default 900 — bounds orphaned workers "
                        "whose sweep finished before they started; pass "
                        "0 for a standing fleet that only STOP ends)")
    args = p.parse_args(argv)
    stats = worker_loop(WorkerConfig(
        root=args.shared_dir, worker_id=args.worker_id,
        poll_s=args.poll, lease_ttl_s=args.lease_ttl,
        max_idle_s=args.max_idle if args.max_idle > 0 else None))
    print("dse-worker: " + " ".join(f"{k}={v}"
                                    for k, v in sorted(stats.items())))


def dse_coordinator_main(argv) -> None:
    """Drive a sweep; external dse-worker processes supply the compute."""
    p = _dse_parser()
    p.prog = "run.py dse-coordinator"
    p.add_argument("--timeout", type=float, default=3600.0,
                   help="seconds to wait for external workers to finish "
                        "all outstanding evaluations")
    args = p.parse_args(argv)
    if args.network == "all":
        print("dse-coordinator needs a single --network", file=sys.stderr)
        sys.exit(2)
    if not args.shared_dir:
        print("dse-coordinator requires --shared-dir", file=sys.stderr)
        sys.exit(2)
    if args.distributed or args.workers:
        print("dse-coordinator spawns no local workers; start "
              "'dse-worker --shared-dir ...' processes instead of "
              "passing --distributed/--workers", file=sys.stderr)
        sys.exit(2)
    if args.compact_journal:
        _compact_journal(shared_dir=args.shared_dir)
        return
    from repro.dse import DistribConfig, run_coordinator
    from repro.dse.report import frontier_table, summarize
    dist = DistribConfig(root=args.shared_dir, batch_size=args.batch_size,
                         lease_ttl_s=args.lease_ttl,
                         timeout_s=args.timeout)
    finish_obs = _setup_obs(args)
    try:
        res = run_coordinator(_dse_config_from_args(args), dist)
    finally:
        finish_obs()
    print(summarize(res))
    print(frontier_table(res.frontier))
    _print_fleet(res.stats)
    _write_frontier(res, args.frontier_out)


def serve_dse_main(argv) -> None:
    """HTTP-less local client of the mapping service: build one
    ``MappingRequest`` from flags (or ``--request-json``), answer it
    through a ``MappingService`` over a persistent journal, and print
    the response. Re-running an identical request is served from the
    journal cache with zero new mapping searches (``served_from=journal
    evaluated=0``)."""
    import json
    from repro.core.search import MODES, OBJECTIVES, STRATEGIES
    from repro.dse import EXPLORERS, SPACES

    p = argparse.ArgumentParser(
        prog="run.py serve-dse",
        description="Answer one deployment request ('best (arch, "
                    "mapping) for this network under this budget') "
                    "through the mapping service (repro.serve).")
    p.add_argument("--network", default="resnet18",
                   help="network name or zoo scenario (see 'run.py "
                        "workloads')")
    p.add_argument("--family", default="dram_pim", choices=sorted(SPACES))
    p.add_argument("--mode", default="transform", choices=MODES)
    p.add_argument("--strategy", default="forward", choices=STRATEGIES)
    p.add_argument("--objective", default="latency", choices=OBJECTIVES)
    p.add_argument("--blend-alpha", type=float, default=0.5)
    p.add_argument("--explorer", default="evolve", choices=EXPLORERS)
    p.add_argument("--budget", type=int, default=16)
    p.add_argument("--seed", type=int, default=1)
    p.add_argument("--candidates", type=int, default=8)
    p.add_argument("--max-steps", type=int, default=2048)
    p.add_argument("--area-budget", type=float, default=None,
                   metavar="MM2", help="only deploy archs within this "
                   "area proxy (iso-area constraint)")
    p.add_argument("--deadline", type=float, default=None, metavar="S",
                   help="wall-clock bound; the response is the "
                        "best-so-far frontier when it expires")
    p.add_argument("--distributed", type=int, default=0, metavar="N",
                   help="fan the sweep out over N local worker "
                        "processes (large budgets)")
    p.add_argument("--include-mapping", action="store_true",
                   help="materialize the winner's per-layer loop nests "
                        "into the response")
    p.add_argument("--journal", default=None,
                   help="service journal path (default: "
                        "dse_runs/service.jsonl) — the cross-request "
                        "result cache")
    p.add_argument("--request-json", default=None, metavar="JSON",
                   help="full request as a JSON object (overrides the "
                        "per-field flags)")
    p.add_argument("--json", action="store_true",
                   help="print the full MappingResponse as JSON")
    _obs_flags(p)
    args = p.parse_args(argv)

    from repro.dse.driver import JOURNAL_ROOT
    from repro.serve import MappingRequest, MappingService
    if args.request_json:
        req = MappingRequest.from_dict(json.loads(args.request_json))
    else:
        req = MappingRequest(
            network=args.network, family=args.family, mode=args.mode,
            strategy=args.strategy, objective=args.objective,
            blend_alpha=args.blend_alpha, explorer=args.explorer,
            budget=args.budget, seed=args.seed,
            n_candidates=args.candidates, max_steps=args.max_steps,
            area_budget_mm2=args.area_budget, deadline_s=args.deadline,
            distributed=args.distributed,
            include_mapping=args.include_mapping)
    journal = args.journal or os.path.join(JOURNAL_ROOT, "service.jsonl")
    # telemetry before the service: it binds its registry at construction
    finish_obs = _setup_obs(args)
    svc = MappingService(journal_path=journal)
    try:
        resp = svc.request(req)
    finally:
        svc.close()
        finish_obs()
    print(f"serve-dse: request={resp.request_key[:12]} "
          f"status={resp.status} served_from={resp.served_from} "
          f"evaluated={resp.evaluated} from_journal={resp.from_journal} "
          f"deadline_hit={resp.deadline_hit} wall_s={resp.wall_s:.1f}")
    if resp.best is not None:
        print(f"serve-dse: best {resp.best['arch_name']} "
              f"latency_ms={resp.best['total_ns'] / 1e6:.3f} "
              f"energy_J={resp.best['energy_pj'] / 1e12:.1f} "
              f"area_mm2={resp.best['area_mm2']:.2f}")
    else:
        print("serve-dse: no scored arch fits the area budget "
              f"({req.area_budget_mm2} mm2)")
    print(f"serve-dse: frontier={len(resp.frontier_points)} points, "
          f"journal={journal}")
    if resp.mapping:
        for lay in resp.mapping:
            print(f"serve-dse: mapping {lay['layer']}: "
                  f"latency_ns={lay['latency_ns']:.0f} "
                  f"transformed={lay['transformed']}")
    if args.json:
        print(resp.to_json(indent=2))


def serve_http_main(argv) -> None:
    """Run the mapping service as an HTTP server (``repro.serve.
    transport``, DESIGN.md Section 13): POST /v1/mapping answers
    deployment requests with the same wire forms ``serve-dse`` prints,
    GET /v1/metrics scrapes the ``serve.*``/``engine.*`` counters in
    Prometheus text format, GET /v1/healthz is liveness. Serves until
    interrupted; SIGINT drains in-flight sweeps before exiting."""
    p = argparse.ArgumentParser(
        prog="run.py serve-http",
        description="Serve mapping requests over HTTP "
                    "(repro.serve.MappingHTTPServer).")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8099,
                   help="listening port (0 = ephemeral, printed at "
                        "startup)")
    p.add_argument("--journal", default=None,
                   help="service journal path (default: "
                        "dse_runs/service.jsonl) — the cross-request "
                        "result cache")
    p.add_argument("--max-workers", type=int, default=1, metavar="N",
                   help="concurrent sweep threads")
    p.add_argument("--max-pending", type=int, default=32, metavar="N",
                   help="admission cap: shed (HTTP 429) once N distinct "
                        "requests are waiting (0 = unbounded)")
    p.add_argument("--memo-cap", type=int, default=256, metavar="N",
                   help="LRU size of the response memo (and the "
                        "loop-nest cache)")
    p.add_argument("--persist-dir", default=None, metavar="DIR",
                   help="write-through the memo/nest caches to DIR so "
                        "a restarted server starts warm")
    p.add_argument("--compact-every", type=float, default=None,
                   metavar="S", help="background maintenance cadence: "
                   "compact the journal and persisted caches every S "
                   "seconds")
    p.add_argument("--bundle-cap", type=int, default=8, metavar="N",
                   help="arch bundles the shared overlap engine "
                        "retains across requests (LRU)")
    p.add_argument("--flight-cap", type=int, default=256, metavar="N",
                   help="per-request flight-recorder ring size "
                        "(GET /v1/debug/requests; 0 disables)")
    p.add_argument("--slow-threshold", type=float, default=1.0,
                   metavar="S", help="requests at/above S seconds keep "
                   "full detail in the slow ring")
    p.add_argument("--window", type=float, default=60.0, metavar="S",
                   help="sliding window (seconds) behind the recent "
                        "p50/p99 latency gauges (0 disables)")
    p.add_argument("--slo-target", type=float, default=None, metavar="S",
                   help="latency SLO target in seconds: publishes "
                        "serve.slo.ok/breach counters and the windowed "
                        "burn-rate gauge")
    p.add_argument("--slo-goal", type=float, default=0.99,
                   help="SLO goal fraction (default: %(default)s)")
    _obs_flags(p)
    args = p.parse_args(argv)

    from repro.dse.driver import JOURNAL_ROOT
    from repro.serve import MappingHTTPServer, MappingService
    journal = args.journal or os.path.join(JOURNAL_ROOT, "service.jsonl")
    # telemetry before the service: it binds its registry at construction
    finish_obs = _setup_obs(args)
    svc = MappingService(
        journal_path=journal,
        max_workers=args.max_workers,
        max_pending=args.max_pending or None,
        memo_cap=args.memo_cap, nest_cap=args.memo_cap,
        persist_dir=args.persist_dir,
        compact_every_s=args.compact_every,
        engine_bundle_cap=args.bundle_cap,
        flight_cap=args.flight_cap,
        slow_threshold_s=args.slow_threshold,
        window_s=args.window,
        slo_target_s=args.slo_target,
        slo_goal=args.slo_goal)
    server = MappingHTTPServer(svc, host=args.host, port=args.port)
    print(f"serve-http: listening on {server.url} journal={journal} "
          f"workers={args.max_workers} max_pending={args.max_pending}",
          flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        print("serve-http: draining...", flush=True)
    finally:
        server.close()
        # the saved snapshot carries the flight ring so obs-report can
        # render the per-request section offline
        finish_obs(extra={"flight": svc.flight.snapshot()}
                   if svc.flight.enabled else None)


def workloads_main(argv) -> None:
    """List the zoo scenarios the lowering layer serves (per-block layer
    and MAC counts, plus the whole-model block multiplier)."""
    p = argparse.ArgumentParser(
        prog="run.py workloads",
        description="List LLM workload scenarios (repro.workloads): "
                    "every zoo arch x {prefill, decode} lowered to "
                    "overlap-searchable LayerSpec networks. Any listed "
                    "name (or the grammar '<arch>[:phase][@length]"
                    "[xblocks]') works with 'dse --network', "
                    "'serve-dse --network' and a MappingRequest.")
    p.add_argument("--smoke", action="store_true",
                   help="list the reduced smoke configs (CPU-test scale)")
    p.add_argument("--arch", default=None,
                   help="only scenarios of this zoo arch")
    args = p.parse_args(argv)

    from repro.configs import get_config
    from repro.workloads import list_scenarios, parse_scenario, \
        lower_scenario
    print(f"{'scenario':44s} {'family':7s} {'layers':>6s} "
          f"{'macs/block':>14s} {'blocks':>6s} {'macs/model':>14s}")
    for name in list_scenarios(smoke=args.smoke):
        sc = parse_scenario(name)
        if args.arch and args.arch.replace("-", "_") not in (sc.arch_id,):
            continue
        cfg = sc.config()
        layers, _ = lower_scenario(sc)
        macs = sum(l.macs for l in layers)
        if cfg.family in ("hybrid", "audio"):
            # the lowered tranche mixes block kinds with different
            # repeat counts (SSM vs shared-attention / enc vs dec), so
            # a single whole-model multiplier would mislead
            blocks_s, total_s = "mixed", "-"
        else:
            blocks_s = str(max(1, cfg.n_layers))
            total_s = f"{macs * max(1, cfg.n_layers):,d}"
        print(f"{name:44s} {cfg.family:7s} {len(layers):6d} "
              f"{macs:14,d} {blocks_s:>6s} {total_s:>14s}")


def main() -> None:
    argv = sys.argv[1:]
    if argv and argv[0] == "dse":
        dse_main(argv[1:])
    elif argv and argv[0] == "serve-dse":
        serve_dse_main(argv[1:])
    elif argv and argv[0] == "serve-http":
        serve_http_main(argv[1:])
    elif argv and argv[0] == "dse-worker":
        dse_worker_main(argv[1:])
    elif argv and argv[0] == "dse-coordinator":
        dse_coordinator_main(argv[1:])
    elif argv and argv[0] == "obs-report":
        obs_report_main(argv[1:])
    elif argv and argv[0] == "obs-profile":
        obs_profile_main(argv[1:])
    elif argv and argv[0] == "workloads":
        workloads_main(argv[1:])
    elif not argv or argv[0] == "bench":
        bench_main(argv[1:] if argv else [])
    else:
        print(f"unknown subcommand {argv[0]!r}; use 'bench', 'dse', "
              "'serve-dse', 'serve-http', 'dse-worker', "
              "'dse-coordinator', 'obs-report', 'obs-profile' or "
              "'workloads'", file=sys.stderr)
        sys.exit(2)


if __name__ == '__main__':
    main()
