"""One benchmark per paper figure/table (Figs 4, 10-17 + Section IV-F).

Each ``fig*`` function returns CSV rows ``name,us_per_call,derived``
where ``derived`` carries the paper-comparable statistic (speedups).
"""
from __future__ import annotations

import random
import time
from typing import List

import numpy as np

from repro.core import (LayerSpec, SearchConfig, analyze, describe,
                        dram_pim, evaluate_chain, generate_analytical,
                        generate_exhaustive, heuristic_mapping,
                        optimize_network, random_mapping,
                        ready_steps_analytical, ready_steps_exhaustive)
from .common import (comparison_points, csv_row, make_arch, search,
                     timed, QUICK)

NETS = ["resnet18", "vgg16"] + ([] if QUICK else ["resnet50"])
NETS_ALL = ["resnet18", "vgg16", "resnet50"]


def fig4_motivation() -> List[str]:
    """Overlap available in Timeloop-best mappings (normalized overlapped
    latency reduction per layer; higher = more overlap)."""
    rows = []
    for net in NETS:
        t0 = time.perf_counter()
        ro, desc = search(net, "dram2", "original")
        maps = [l.mapping for l in ro.layers]
        ov = evaluate_chain(maps, desc.edges, "overlap")
        fracs = []
        for i in range(1, len(maps)):
            seq = ro.layers[i].latency_ns
            ovl = ov.layers[i].latency_ns
            fracs.append(max(0.0, 1.0 - ovl / seq))
        fracs = np.asarray(fracs)
        lim = float((fracs <= 0.3).mean())
        rows.append(csv_row(
            f"fig4_motivation_{net}", (time.perf_counter() - t0) * 1e6,
            f"median_overlap_frac={np.median(fracs):.2f};"
            f"layers_leq30pct={lim:.2f};max={fracs.max():.2f}"))
    return rows


def fig10_overall() -> List[str]:
    """Overall comparison of the six optimization points, plus the
    beyond-paper coordinate-descent refinement."""
    rows = []
    for net in NETS:
        t0 = time.perf_counter()
        p = comparison_points(net)
        sp_t = p["best_original"] / p["best_transform"]
        sp_o = p["best_original"] / p["best_overlap"]
        rows.append(csv_row(
            f"fig10_overall_{net}", (time.perf_counter() - t0) * 1e6,
            f"best_original_ms={p['best_original']:.1f};"
            f"best_overlap_x={sp_o:.2f};best_transform_x={sp_t:.2f};"
            f"transform_vs_origtransform_x="
            f"{p['original_transform'] / p['best_transform']:.2f}"))
    # beyond-paper refinement (one net in quick mode to bound runtime)
    for net in (["resnet18"] if QUICK else NETS):
        t0 = time.perf_counter()
        rr, desc = search(net, "dram2", "transform", "forward+refine")
        p = comparison_points(net)
        rows.append(csv_row(
            f"fig10_refined_{net}", (time.perf_counter() - t0) * 1e6,
            f"refined_transform_x="
            f"{p['best_original'] / (rr.total_ns / 1e6):.2f}"))
    return rows


def fig11_vs_overlapim() -> List[str]:
    """Equal-runtime comparison vs OverlaPIM (exhaustive O(N*M) overlap
    analysis): candidates evaluated within a fixed time budget."""
    rows = []
    layer_p = LayerSpec("p", K=32, C=16, P=16, Q=16, R=3, S=3, pad=1)
    layer_c = LayerSpec("c", K=32, C=32, P=16, Q=16, R=3, S=3, pad=1)
    arch = dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=256)
    budget_s = 2.0 if QUICK else 10.0
    for name, fn in (("fast", ready_steps_analytical),
                     ("overlapim", ready_steps_exhaustive)):
        rng = random.Random(0)
        mp = heuristic_mapping(layer_p, arch, 512)
        n_eval, t0 = 0, time.perf_counter()
        while time.perf_counter() - t0 < budget_s:
            mc = random_mapping(layer_c, arch, rng, 512)
            fn(mp, mc)
            n_eval += 1
        rows.append(csv_row(
            f"fig11_equal_time_{name}", budget_s * 1e6,
            f"mappings_analyzed={n_eval}"))
    return rows


def fig12_perlayer() -> List[str]:
    """Per-layer speedup = sequential latency / incremental completion
    time under the overlapped schedule (end_i - end_{i-1}) — the paper's
    per-layer view of where overlap absorbs a layer's cost."""
    rows = []
    for net in NETS:
        t0 = time.perf_counter()
        ro, desc = search(net, "dram2", "original")
        rt, _ = search(net, "dram2", "transform")
        ends = [l.end_ns for l in rt.layers]
        incr = [ends[0]] + [max(ends[i] - max(ends[:i]), 1e-9)
                            for i in range(1, len(ends))]
        sp = np.asarray([o.perf.sequential_ns / max(d, 1e-9)
                         for o, d in zip(ro.layers, incr)][1:])
        rows.append(csv_row(
            f"fig12_perlayer_{net}", (time.perf_counter() - t0) * 1e6,
            f"min_x={sp.min():.2f};median_x={np.median(sp):.2f};"
            f"max_x={sp.max():.2f};layers_gt2x={(sp > 2).mean():.2f}"))
    return rows


def fig13_memcap() -> List[str]:
    """Sensitivity to per-layer memory capacity (1/2/4 channels)."""
    rows = []
    for net in (["resnet18"] if QUICK else NETS_ALL):
        for ak in ("dram1", "dram2", "dram4"):
            t0 = time.perf_counter()
            p = comparison_points(net, ak)
            rows.append(csv_row(
                f"fig13_memcap_{net}_{ak}",
                (time.perf_counter() - t0) * 1e6,
                f"best_transform_x="
                f"{p['original_transform'] / p['best_transform']:.2f};"
                f"overlap_transform_x="
                f"{p['original_transform'] / p['overlap_transform']:.2f}"
            ))
    return rows


def fig14_runtime() -> List[str]:
    """Analytical vs exhaustive overlap-analysis runtime scaling."""
    rows = []
    sizes = [(8, 8, 64), (16, 8, 128), (16, 16, 256)] \
        + ([] if QUICK else [(32, 16, 512)])
    for p, q, cols in sizes:
        layer_p = LayerSpec("p", K=16, C=8, P=p, Q=q, R=3, S=3, pad=1)
        layer_c = LayerSpec("c", K=16, C=16, P=p, Q=q, R=3, S=3, pad=1)
        arch = dram_pim(channels_per_layer=2, banks_per_channel=2,
                        columns_per_bank=cols)
        mp = heuristic_mapping(layer_p, arch, 4096)
        mc = heuristic_mapping(layer_c, arch, 4096)
        n_spaces = mp.n_banks * mp.n_steps * mc.n_banks * mc.n_steps
        us_a, _ = timed(ready_steps_analytical, mp, mc, repeats=3)
        us_e, _ = timed(ready_steps_exhaustive, mp, mc)
        rows.append(csv_row(
            f"fig14_runtime_NxM_{n_spaces}", us_a,
            f"analytical_us={us_a:.0f};exhaustive_us={us_e:.0f};"
            f"speedup_x={us_e / us_a:.1f}"))
    return rows


def fig15_search_methods() -> List[str]:
    rows = []
    for net in NETS:
        base = None
        for strat in ("backward", "forward", "middle_output",
                      "middle_overall"):
            t0 = time.perf_counter()
            rt, _ = search(net, "dram2", "transform", strat)
            if base is None:
                base = rt.total_ns
            rows.append(csv_row(
                f"fig15_search_{net}_{strat}",
                (time.perf_counter() - t0) * 1e6,
                f"total_ms={rt.total_ns / 1e6:.1f};"
                f"vs_backward_x={base / rt.total_ns:.2f}"))
    return rows


def fig16_reram() -> List[str]:
    t0 = time.perf_counter()
    p = comparison_points("resnet18", "reram")
    return [csv_row(
        "fig16_reram_resnet18", (time.perf_counter() - t0) * 1e6,
        f"best_overlap_x={p['best_original'] / p['best_overlap']:.2f};"
        f"best_transform_x="
        f"{p['best_original'] / p['best_transform']:.2f}")]


def fig17_bert() -> List[str]:
    t0 = time.perf_counter()
    p = comparison_points("bert_encoder")
    return [csv_row(
        "fig17_bert_encoder", (time.perf_counter() - t0) * 1e6,
        f"best_overlap_x={p['best_original'] / p['best_overlap']:.2f};"
        f"best_transform_x="
        f"{p['best_original'] / p['best_transform']:.2f}")]


def sec4f_dataspace_generation() -> List[str]:
    """Section IV-F: analytical O(n) generation vs recursive enumeration
    (Timeloop: ~600s -> <60s; same contrast, smaller absolute sizes)."""
    rows = []
    layer = LayerSpec("l", K=64, C=32, P=28, Q=28, R=3, S=3, pad=1)
    arch = dram_pim(channels_per_layer=2, banks_per_channel=8,
                    columns_per_bank=2048)
    m = heuristic_mapping(layer, arch, 8192)
    us_a, da = timed(generate_analytical, m, repeats=3)
    us_e, de = timed(generate_exhaustive, m)
    assert da.equals(de)
    rows.append(csv_row(
        "sec4f_dataspace_gen", us_a,
        f"n_spaces={da.n_spaces};analytical_us={us_a:.0f};"
        f"recursive_us={us_e:.0f};speedup_x={us_e / us_a:.1f}"))
    return rows
