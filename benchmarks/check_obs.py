"""Observability smoke checker: validate a traced run's telemetry.

Takes the JSONL trace (``--trace-out``) and metrics snapshot
(``--metrics-out``) left behind by a ``run.py dse`` sweep and
cross-checks them against the sweep's known outcome:

* every trace line parses as JSON and is a well-formed span/event
  (name, nesting depth, non-negative duration),
* at least one ``dse.sweep`` span was recorded,
* the ``dse.evaluated`` / ``dse.journal_hits`` counters equal the
  values the sweep printed (``--expect-evaluated`` /
  ``--expect-from-journal``),
* whenever anything was evaluated, the engine published its cache
  counters and the per-point ``dse.eval_seconds`` histogram holds
  exactly one observation per evaluation.

Exit 1 on any mismatch — the CI-sized proof that the telemetry a
future perf investigation would reach for is actually being recorded,
and recorded consistently. (The determinism half — telemetry must not
change results — is enforced by ``tests/test_obs.py``.)
"""
import argparse
import json
import sys
from typing import List


def check_trace(path: str, errors: List[str]) -> List[dict]:
    """Parse every trace line; collect malformed ones into ``errors``."""
    events = []
    try:
        fh = open(path, "r", encoding="utf-8")
    except OSError as e:
        errors.append(f"trace unreadable: {e}")
        return events
    with fh:
        for lineno, line in enumerate(fh, 1):
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                errors.append(f"trace line {lineno}: unparsable JSON")
                continue
            kind = ev.get("ev")
            if kind not in ("span", "event"):
                errors.append(f"trace line {lineno}: ev={kind!r}")
                continue
            if "name" not in ev:
                errors.append(f"trace line {lineno}: missing name")
            if kind == "span" and not (ev.get("dur_s", -1) >= 0
                                       and ev.get("depth", -1) >= 0):
                errors.append(f"trace line {lineno}: bad span fields")
            events.append(ev)
    if not any(e.get("name") == "dse.sweep" and e.get("ev") == "span"
               for e in events):
        errors.append("no dse.sweep span in the trace")
    return events


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--trace", required=True,
                   help="JSONL trace written by --trace-out")
    p.add_argument("--metrics", required=True,
                   help="snapshot JSON written by --metrics-out")
    p.add_argument("--expect-evaluated", type=int, default=None,
                   metavar="N", help="required dse.evaluated count")
    p.add_argument("--expect-from-journal", type=int, default=None,
                   metavar="N", help="required dse.journal_hits count")
    args = p.parse_args()

    errors: List[str] = []
    events = check_trace(args.trace, errors)

    try:
        with open(args.metrics, "r", encoding="utf-8") as fh:
            snap = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"metrics snapshot unreadable: {e}")
        snap = {}
    counters = snap.get("counters") or {}

    for name, expect in (("dse.evaluated", args.expect_evaluated),
                         ("dse.journal_hits",
                          args.expect_from_journal)):
        if expect is None:
            continue
        got = int(counters.get(name, 0))
        if got != expect:
            errors.append(f"{name}={got}, expected {expect}")

    evaluated = int(counters.get("dse.evaluated", 0))
    if evaluated:
        if not any(k.startswith("engine.") for k in counters):
            errors.append("evaluations ran but the engine published "
                          "no cache counters")
        n_lat = int(((snap.get("histograms") or {})
                     .get("dse.eval_seconds") or {}).get("count", 0))
        if n_lat != evaluated:
            errors.append(f"dse.eval_seconds holds {n_lat} "
                          f"observations for {evaluated} evaluations")

    for e in errors:
        print(f"check_obs: FAIL {e}")
    if errors:
        return 1
    print(f"check_obs: OK ({len(events)} trace events, "
          f"evaluated={evaluated}, "
          f"journal_hits={int(counters.get('dse.journal_hits', 0))})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
