"""Aggregate the dry-run JSONs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh: str = "16x16", plan: str = "tp") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") == mesh and rec.get("plan", "tp") == plan:
            out.append(rec)
    return out


def roofline_rows(mesh: str = "16x16") -> List[str]:
    rows = []
    for rec in load_cells(mesh):
        name = f"roofline_{rec['arch']}_{rec['shape']}_{mesh}"
        if rec.get("status") != "ok":
            rows.append(f"{name},0.000,{rec.get('status', 'missing')}")
            continue
        r = rec["roofline"]
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / total if total else 0.0
        rows.append(
            f"{name},{rec['compile_s'] * 1e6:.0f},"
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};"
            f"bottleneck={r['bottleneck']};roofline_frac={frac:.3f};"
            f"useful_flops_ratio={rec['useful_flops_ratio']:.2f};"
            f"peak_GiB={rec['memory']['peak_bytes_per_device'] / 2**30:.2f}"
        )
    return rows


def markdown_table(mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | "
        "bottleneck | roofline frac | MODEL/HLO flops | peak GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(mesh):
        if rec.get("status") != "ok":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                f"{rec.get('status', '?')} | — | — | — |")
            continue
        r = rec["roofline"]
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / total if total else 0.0
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['bottleneck']} | {frac:.2f} | "
            f"{rec['useful_flops_ratio']:.2f} | "
            f"{rec['memory']['peak_bytes_per_device'] / 2**30:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
