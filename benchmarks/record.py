"""Machine-readable benchmark journal (``BENCH_search.json``).

The CSV stream stays the human-facing output; this module mirrors the
perf-relevant rows into a committed JSON file so the throughput/latency
trajectory is tracked across PRs. Writers merge: existing keys are
overwritten, unrelated keys survive, so the bench suite and the ``dse``
subcommand can update their own sections independently.

Schema::

    {"schema": 1,
     "rows": {"<bench row name>": {"us_per_call": ..., "derived": ...}},
     "dse": {"<family>/<network>/<mode>[/<objective>]": {summary numbers}},
     "frontier": {"<network>/<arch>": [{objective, total_ns, energy_pj,
                                        move_energy_pj, edp_ns_pj}, ...]}}

The ``frontier`` section holds the per-arch latency-vs-EDP trade of the
energy-aware mapping search (one point per search objective), written by
``bench_search.objective_frontier``.
"""
from __future__ import annotations

import json
import os
from typing import Dict

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_search.json")


def _load(path: str = BENCH_JSON) -> Dict:
    if os.path.exists(path):
        try:
            with open(path, "r", encoding="utf-8") as fh:
                data = json.load(fh)
            if isinstance(data, dict):
                data.setdefault("schema", 1)
                data.setdefault("rows", {})
                data.setdefault("dse", {})
                data.setdefault("frontier", {})
                return data
        except (json.JSONDecodeError, OSError):
            pass
    return {"schema": 1, "rows": {}, "dse": {}, "frontier": {}}


def _dump(data: Dict, path: str = BENCH_JSON) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)


def update_rows(rows: Dict[str, Dict], path: str = BENCH_JSON) -> None:
    """Merge ``{name: {"us_per_call": ..., "derived": ...}}`` rows."""
    data = _load(path)
    data["rows"].update(rows)
    _dump(data, path)


def get_row(name: str, path: str = BENCH_JSON) -> Dict:
    """The incumbent row (``{}`` if absent) — read *before* overwriting
    it, so a bench can report its speedup against the committed value
    (e.g. the batched scorer's sustained-throughput row derives its
    speedup from the pre-PR engine row it replaces)."""
    return _load(path)["rows"].get(name, {})


def update_frontier(key: str, points, path: str = BENCH_JSON) -> None:
    """Replace the objective-frontier point list under ``frontier[key]``
    (``key`` is ``<network>/<arch>``; one point per search objective)."""
    data = _load(path)
    data["frontier"][key] = points
    _dump(data, path)


def update_dse(key: str, summary: Dict, path: str = BENCH_JSON) -> None:
    """Merge one DSE sweep summary under ``dse[key]``.

    Guards keep the tracked perf trajectory honest: a fully or mostly
    journal-resumed sweep must not clobber the genuine search-cost
    numbers of the run that populated the journal (``evaluated`` below
    the incumbent's means the rerun replayed, not searched), and a
    *smaller-budget* run (a CI smoke, a quick local check) must not
    replace a paper-scale record — the file tracks the trajectory
    across PRs, not whichever sweep happened to run last."""
    data = _load(path)
    prev = data["dse"].get(key)
    if prev is not None:
        if summary.get("budget", 0) < prev.get("budget", 0):
            return          # smoke/quick run vs a paper-scale record
        if summary.get("budget", 0) == prev.get("budget", 0) \
                and summary.get("evaluated", 0) < prev.get("evaluated", 0):
            return          # same sweep replayed from the journal
        # a *larger*-budget sweep always records: its frontier strictly
        # extends the incumbent's even when the overlap replayed
    data["dse"][key] = summary
    _dump(data, path)
