"""Mapping-search throughput benchmarks (batched engine vs pre-engine).

Two row families:

* ``bench_search.scoring_*`` — candidate scoring throughput on resnet18,
  mode=transform, against a committed chain: the pre-engine per-candidate
  path (``search._score_forward``) vs ``OverlapEngine.score_forward_batch``.
  ``engine_cold`` scores a fresh pool on a fresh engine; ``engine_sustained``
  re-scores the same pools (the regime the refine loop and repeated
  strategy passes operate in, where memoized analysis is reused).
* ``bench_search.search_<net>_<mode>_<strategy>`` — end-to-end
  ``optimize_network`` wall time (engine path) for vgg16 / resnet18 /
  bert_encoder across all four strategies x three modes; the derived
  column carries the searched ``total_ms`` and candidates/sec so future
  PRs can track search-throughput regressions.

Every row is additionally mirrored into ``BENCH_search.json`` (see
``benchmarks.record``) so the perf trajectory is machine-readable.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

from repro.core import (MODES, STRATEGIES, SearchConfig, describe,
                        dram_pim, optimize_network, reram_pim, tpu_spatial)
from repro.core.engine import OverlapEngine, optimize_network_engine
from repro.core.search import _consumers_of, _score_forward, candidates

from . import record
from .common import MAX_STEPS, N_CANDIDATES, QUICK, SEED, csv_row, \
    make_arch, search


def _emit(name: str, us_per_call: float, derived: str) -> str:
    """CSV row that is also mirrored into BENCH_search.json, so the
    perf trajectory of the search path is machine-readable across PRs."""
    record.update_rows({name: {"us_per_call": round(us_per_call, 3),
                               "derived": derived}})
    return csv_row(name, us_per_call, derived)


def _scoring_setup():
    arch = make_arch("dram2")
    desc = describe("resnet18")
    cfg = SearchConfig(n_candidates=N_CANDIDATES, seed=SEED,
                       max_steps=MAX_STEPS, mode="transform")
    res, _ = search("resnet18", "dram2", "transform", "forward")
    done = {i: lr for i, lr in enumerate(res.layers)}
    pools = [candidates(desc.layers[i], arch, cfg, salt=i)
             for i in range(len(desc.layers))]
    # has_consumer is per-layer graph metadata, precomputed here so the
    # timed passes measure scoring, not edge-list scans
    scored = [(i, p, bool(_consumers_of(desc.edges, i)))
              for i, p in enumerate(pools) if desc.edges[i]]
    n = sum(len(p) for _, p, _ in scored)
    return desc, done, scored, n


def scoring_throughput():
    """Acceptance rows: batched-engine scoring throughput on resnet18,
    mode=transform. ``engine_cold`` scores fresh pools on a fresh engine;
    ``engine_sustained_batched`` re-scores the same pools (best of 5 warm
    passes — the refine-loop / repeat-sweep regime) and derives its
    speedup against the *incumbent* sustained row read from
    BENCH_search.json before overwrite, i.e. against the committed
    pre-PR engine on the regeneration run of a PR."""
    desc, done, scored, n = _scoring_setup()
    prev = record.get_row("bench_search.scoring_engine_sustained")

    t0 = time.perf_counter()
    for i, pool, has_cons in scored:
        for m in pool:
            _score_forward(i, m, desc.edges, done, "transform", has_cons)
    t_ref = time.perf_counter() - t0

    eng = OverlapEngine()

    def engine_pass():
        t0 = time.perf_counter()
        for i, pool, has_cons in scored:
            eng.score_forward_batch(i, pool, desc.edges, done, "transform",
                                    has_cons)
        return time.perf_counter() - t0

    t_cold = engine_pass()
    t_sust = min(engine_pass() for _ in range(5))
    sust_us = t_sust / n * 1e6
    prev_us = float(prev.get("us_per_call", 0.0))
    vs_prev = (f";prev_us={prev_us};speedup_vs_prev={prev_us / sust_us:.2f}x"
               if prev_us else "")

    yield _emit("bench_search.scoring_ref", t_ref / n * 1e6,
                  f"cands_per_s={n / t_ref:.0f}")
    yield _emit("bench_search.scoring_engine_cold", t_cold / n * 1e6,
                  f"cands_per_s={n / t_cold:.0f}")
    yield _emit("bench_search.scoring_engine_sustained", sust_us,
                  f"cands_per_s={n / t_sust:.0f}")
    yield _emit("bench_search.scoring_engine_sustained_batched", sust_us,
                  f"cands_per_s={n / t_sust:.0f}{vs_prev}")
    yield _emit("bench_search.scoring_speedup", 0.0,
                  f"cold={t_ref / t_cold:.2f}x"
                  f";sustained={t_ref / t_sust:.2f}x")


def obs_overhead():
    """Telemetry-overhead guard on the sustained scoring hot path. The
    engine's per-candidate loops keep plain-int counters and publish
    deltas only at search end (``publish_metrics``), so enabling the
    metrics registry must not slow sustained ``score_forward_batch``
    passes measurably; the derived column records the enabled/disabled
    ratio (same pass, best of 5 each, telemetry on without a trace
    sink). ``tests/test_obs.py`` enforces the structural half (zero
    obs dispatches from the hot loop); this row tracks the wall-clock
    half across PRs."""
    from repro import obs

    desc, done, scored, n = _scoring_setup()
    eng = OverlapEngine()

    def engine_pass():
        t0 = time.perf_counter()
        for i, pool, has_cons in scored:
            eng.score_forward_batch(i, pool, desc.edges, done, "transform",
                                    has_cons)
        return time.perf_counter() - t0

    engine_pass()                   # warm the memo tables
    t_off = min(engine_pass() for _ in range(5))
    obs.enable()                    # registry only, no trace sink
    try:
        t_on = min(engine_pass() for _ in range(5))
        eng.publish_metrics()
    finally:
        obs.disable()
    yield _emit("bench_search.obs_overhead_sustained", t_on / n * 1e6,
                f"off_us={t_off / n * 1e6:.3f}"
                f";on_us={t_on / n * 1e6:.3f}"
                f";ratio={t_on / t_off:.3f}x")


def e2e_speedup():
    """End-to-end optimize_network, engine vs pre-engine reference, on
    resnet18 mode=transform with one refine pass (where incremental chain
    re-evaluation matters). Asserts result equality while timing."""
    arch = make_arch("dram2")
    desc = describe("resnet18")
    cfg = SearchConfig(n_candidates=12, seed=SEED, max_steps=2048,
                       mode="transform", refine_passes=1)
    t0 = time.perf_counter()
    a = optimize_network(desc.layers, desc.edges, arch, cfg)
    t_eng = time.perf_counter() - t0
    ref_cfg = SearchConfig(n_candidates=12, seed=SEED, max_steps=2048,
                           mode="transform", refine_passes=1,
                           use_engine=False)
    t0 = time.perf_counter()
    b = optimize_network(desc.layers, desc.edges, arch, ref_cfg)
    t_ref = time.perf_counter() - t0
    if a.total_ns != b.total_ns:  # run.py counts the raise as a failure
        raise AssertionError(
            f"engine diverged from reference: {a.total_ns} != {b.total_ns}")
    yield _emit("bench_search.e2e_resnet18_transform_refine", t_eng * 1e6,
                  f"ref_s={t_ref:.2f};engine_s={t_eng:.2f}"
                  f";speedup={t_ref / t_eng:.2f}x;equal=True")


def objective_frontier():
    """Latency-vs-EDP frontier of the energy-aware transform search:
    resnet18 on each arch factory, searched under every objective. Points
    land in ``BENCH_search.json`` under ``frontier["resnet18/<arch>"]``;
    the derived column reports whether the EDP-objective search strictly
    dominates the latency-only search on EDP. A tie is the expected
    common case at fixed arch (base energy is mapping-invariant, so EDP
    ordering mostly tracks latency); the greedy per-layer search offers
    no guarantee either way, so ``dominates`` is reported, not
    asserted."""
    desc = describe("resnet18")
    n_cand = 8 if QUICK else N_CANDIDATES
    max_steps = 2048 if QUICK else MAX_STEPS
    factories = (("dram_pim", dram_pim()),
                 ("reram_pim", reram_pim()),
                 ("tpu_spatial", tpu_spatial()))
    for arch_name, arch in factories:
        eng = OverlapEngine()   # shared: objectives reuse the analysis
        points = []
        t0 = time.perf_counter()
        for objective in ("latency", "energy", "edp"):
            cfg = SearchConfig(n_candidates=n_cand, seed=SEED,
                               max_steps=max_steps, mode="transform",
                               objective=objective)
            res = optimize_network_engine(desc.layers, desc.edges, arch,
                                          cfg, engine=eng)
            s = res.summary()
            points.append({
                "objective": objective,
                "total_ns": s["total_ns"],
                "energy_pj": s["energy_pj"],
                "move_energy_pj": s["move_energy_pj"],
                "moved_bytes": s["moved_bytes"],
                "edp_ns_pj": s["edp_ns_pj"],
            })
        dt = time.perf_counter() - t0
        record.update_frontier(f"resnet18/{arch_name}", points)
        by_obj = {p["objective"]: p for p in points}
        edp_lat = by_obj["latency"]["edp_ns_pj"]
        edp_edp = by_obj["edp"]["edp_ns_pj"]
        yield _emit(
            f"bench_search.objective_frontier_resnet18_{arch_name}",
            dt * 1e6,
            f"edp_latency_search={edp_lat:.4e}"
            f";edp_edp_search={edp_edp:.4e}"
            f";edp_win={edp_lat / edp_edp:.4f}x"
            f";dominates={edp_edp < edp_lat}")


def worker_scaling():
    """1-vs-N-worker wall time of the distributed sweep subsystem
    (DESIGN.md Section 10) on a resnet18 grid sweep: every arm runs the
    full shared-dir protocol (manifests, leases, shard publish) against
    a fresh directory, so each evaluates all points from scratch. Arms
    are interleaved and the per-arm best of ``reps`` is reported — the
    sandboxed 2-core CI/container hosts this runs on have noisy,
    drifting CPU allocation, and min-of-k is the standard way to read
    a stable number through that. The derived column records the host
    core count next to the speedup: scaling saturates at the physical
    parallelism, so a 4-worker arm on a 2-core box is bounded by the
    2-way optimum (the compute gate keeps it *at* that optimum instead
    of timeslice-thrashing below it)."""
    from repro.dse import DSEConfig, DistribConfig, run_distributed

    budget = 24 if QUICK else 32
    reps = 2 if QUICK else 3
    counts = (1, 2, 4)
    base = dict(family="dram_pim", network="resnet18", mode="transform",
                explorer="grid", budget=budget, seed=SEED,
                n_candidates=4, max_steps=1024)
    walls = {n: [] for n in counts}
    for _ in range(reps):
        for n in counts:
            root = tempfile.mkdtemp(prefix=f"dse-scale-w{n}-")
            try:
                t0 = time.perf_counter()
                res = run_distributed(
                    DSEConfig(**base),
                    DistribConfig(root=root, n_workers=n,
                                  worker_mode="process"))
                walls[n].append(time.perf_counter() - t0)
                if res.stats["evaluated"] != budget:
                    raise AssertionError(
                        f"scaling arm w{n} evaluated "
                        f"{res.stats['evaluated']} != {budget}")
            finally:
                shutil.rmtree(root, ignore_errors=True)
    for n in counts:
        # speedups are paired *within* a rep — the 1-worker arm of the
        # same rep ran under the same host weather — then best-of-reps;
        # the row reports that same rep's wall times, so the headline
        # ratio is always reproducible from the numbers printed next
        # to it
        speedup, w1_wall, wn_wall = max(
            ((w1 / wn, w1, wn) for w1, wn in zip(walls[1], walls[n])),
            key=lambda t: (t[0], -t[2]))
        yield _emit(
            f"bench_search.dse_worker_scaling_w{n}", wn_wall * 1e6,
            f"budget={budget};best_of={reps};wall_s={wn_wall:.2f}"
            f";w1_wall_s={w1_wall:.2f};speedup_vs_1w={speedup:.2f}x"
            f";cores={os.cpu_count()}")


def search_wall():
    """End-to-end optimize_network wall time, engine path, per
    net x mode x strategy."""
    n_cand = 8 if QUICK else N_CANDIDATES
    arch = make_arch("dram2")
    for net in ("vgg16", "resnet18", "bert_encoder"):
        desc = describe(net)
        for mode in MODES:
            for strategy in STRATEGIES:
                cfg = SearchConfig(n_candidates=n_cand, seed=SEED,
                                   max_steps=MAX_STEPS, mode=mode,
                                   strategy=strategy)
                t0 = time.perf_counter()
                res = optimize_network(desc.layers, desc.edges, arch, cfg)
                dt = time.perf_counter() - t0
                cps = len(desc.layers) * n_cand / dt
                yield _emit(
                    f"bench_search.search_{net}_{mode}_{strategy}",
                    dt * 1e6,
                    f"total_ms={res.total_ns / 1e6:.3f}"
                    f";cands_per_s={cps:.0f}")
