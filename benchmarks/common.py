"""Shared helpers for the paper-figure benchmarks.

All searches share a per-(net, arch, mode, strategy) result cache so the
six Section V-A2 comparison points reuse mappings exactly the way the
paper defines them:
  Best Original          — searched on sequential latency, scored sequential
  Best Original Overlap  — same mappings, scored with overlap
  Original Transform     — same mappings, scored with transformation
  Best Overlap           — searched on overlapped latency
  Overlap Transform      — Best Overlap mappings + transformation
  Best Transform         — searched on transformed latency (Fast-OverlaPIM)
"""
from __future__ import annotations

import functools
import os
import time
from typing import Dict, List, Tuple

from repro.core import (SearchConfig, describe, dram_pim, evaluate_chain,
                        optimize_network, reram_pim)

QUICK = os.environ.get("BENCH_FULL", "0") != "1"

N_CANDIDATES = 24 if QUICK else 64
MAX_STEPS = 8192 if QUICK else 16384
SEED = 1

_cache: Dict = {}


def search(net: str, arch_key: str = "dram2", mode: str = "original",
           strategy: str = "forward", n_candidates: int = None,
           max_steps: int = None):
    key = (net, arch_key, mode, strategy, n_candidates, max_steps)
    if key in _cache:
        return _cache[key]
    arch = make_arch(arch_key)
    desc = describe(net)
    refine = 0
    if strategy.endswith("+refine"):
        strategy, refine = strategy[:-len("+refine")], 1
    cfg = SearchConfig(n_candidates=n_candidates or N_CANDIDATES,
                       seed=SEED, max_steps=max_steps or MAX_STEPS,
                       mode=mode, strategy=strategy,
                       refine_passes=refine)
    res = optimize_network(desc.layers, desc.edges, arch, cfg)
    _cache[key] = (res, desc)
    return _cache[key]


def make_arch(key: str):
    if key == "dram1":
        return dram_pim(channels_per_layer=1)
    if key == "dram2":
        return dram_pim(channels_per_layer=2)
    if key == "dram4":
        return dram_pim(channels_per_layer=4)
    if key == "reram":
        return reram_pim(tiles_per_layer=2, blocks_per_tile=8,
                         columns_per_block=1024)
    raise KeyError(key)


def comparison_points(net: str, arch_key: str = "dram2",
                      strategy: str = "forward") -> Dict[str, float]:
    """All six Section V-A2 points, in ms."""
    ro, desc = search(net, arch_key, "original", strategy)
    rv, _ = search(net, arch_key, "overlap", strategy)
    rt, _ = search(net, arch_key, "transform", strategy)
    orig_maps = [l.mapping for l in ro.layers]
    ovl_maps = [l.mapping for l in rv.layers]
    return {
        "best_original": ro.total_ns / 1e6,
        "best_original_overlap": evaluate_chain(
            orig_maps, desc.edges, "overlap").total_ns / 1e6,
        "original_transform": evaluate_chain(
            orig_maps, desc.edges, "transform").total_ns / 1e6,
        "best_overlap": rv.total_ns / 1e6,
        "overlap_transform": evaluate_chain(
            ovl_maps, desc.edges, "transform").total_ns / 1e6,
        "best_transform": rt.total_ns / 1e6,
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"


def timed(fn, *args, repeats: int = 1, **kw) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out
