"""Kernel benchmarks: interpret-mode correctness timing + the HBM-traffic
model that predicts the TPU win of the overlap-fused kernels."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import (attention_ref, flash_attention_op,
                                      hbm_bytes_flash, hbm_bytes_unfused)
from repro.kernels.fused_mlp import (fused_mlp_op, fused_mlp_ref,
                                     hbm_bytes_fused)
from repro.kernels.fused_mlp.ops import hbm_bytes_unfused as \
    mlp_bytes_unfused
from repro.kernels.ssd_scan import ssd_ref, ssd_scan_op
from .common import csv_row, timed


def kernels() -> List[str]:
    rows = []
    # fused MLP: granite_8b-like shard shapes (m=2048 tokens, k=4096,
    # f=14336/16)
    m, k, f = 512, 512, 1024
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32) * 0.3
    w1 = jax.random.normal(ks[1], (k, f), jnp.float32) * 0.05
    w3 = jax.random.normal(ks[2], (k, f), jnp.float32) * 0.05
    w2 = jax.random.normal(ks[3], (f, k), jnp.float32) * 0.05
    us, y = timed(lambda: fused_mlp_op(x, w1, w3, w2, tm=128, tf=256,
                                       interpret=True).block_until_ready())
    err = float(jnp.abs(y - fused_mlp_ref(x, w1, w3, w2)).max())
    M, K, F = 2048, 4096, 14336 // 16
    saved = 1 - hbm_bytes_fused(M, K, F) / mlp_bytes_unfused(M, K, F)
    rows.append(csv_row("kernel_fused_mlp_interpret", us,
                        f"max_err={err:.2e};"
                        f"hbm_saved_at_granite8b_shard={saved:.2f}"))

    # flash attention: 4k-train-like tile
    q = jax.random.normal(ks[0], (8, 512, 64), jnp.float32)
    kk = jax.random.normal(ks[1], (4, 512, 64), jnp.float32)
    vv = jax.random.normal(ks[2], (4, 512, 64), jnp.float32)
    us, ya = timed(lambda: flash_attention_op(
        q, kk, vv, causal=True, tq=128, tk=128,
        interpret=True).block_until_ready())
    err = float(jnp.abs(ya - attention_ref(q, kk, vv)).max())
    BH, SQ, SK, HD = 7 * 16, 4096, 4096, 128  # llava shard, train_4k
    saved = 1 - hbm_bytes_flash(BH, SQ, SK, HD) / \
        hbm_bytes_unfused(BH, SQ, SK, HD)
    rows.append(csv_row("kernel_flash_attn_interpret", us,
                        f"max_err={err:.2e};"
                        f"hbm_saved_at_llava_train={saved:.2f}"))

    # SSD scan: mamba2-780m head geometry
    BHs, S, P, N = 4, 256, 64, 128
    xs = jax.random.normal(ks[0], (BHs, S, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BHs, S, 1)))
    a = -jnp.exp(jax.random.normal(ks[2], (BHs, 1, 1)) * 0.2)
    bm = jax.random.normal(ks[3], (BHs, S, N))
    cm = jax.random.normal(ks[0], (BHs, S, N))
    us, ys = timed(lambda: ssd_scan_op(
        xs, dt, a, bm, cm, chunk=64,
        interpret=True).block_until_ready())
    err = float(jnp.abs(ys - ssd_ref(xs, dt, a, bm, cm)).max())
    rows.append(csv_row("kernel_ssd_scan_interpret", us,
                        f"max_err={err:.2e};chunk=64"))
    return rows
