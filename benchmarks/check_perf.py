"""Performance regression gate over the committed benchmark records.

Reads ``BENCH_search.json`` and ``BENCH_serve.json`` — the numbers
each PR commits from ``benchmarks/run.py`` — and enforces floors and
ceilings on the rows that define the repo's performance story:

* search path: the batched scoring engine must stay sub-microsecond
  sustained and keep its headline speedups (engine vs reference
  end-to-end, batched vs scalar engine), and the PR-7 telemetry
  invariant must hold (obs-on/obs-off overhead ratio near 1x);
* serve path: memo replays stay sub-5ms at p99, warm-restart journal
  serves stay double-digit-ms, load-shedding answers 429 fast, and the
  memo/journal hit rates the caching layers exist for stay high;
* the flight-recorder-derived ``stage_breakdown`` must be present and
  internally consistent (admit + evaluate + respond == total).

The thresholds are deliberately loose — 2-30x slack over the committed
values — so CI noise never trips them; a genuine regression (an
accidentally quadratic scorer, a lock held across a sweep, a cache
that stopped hitting) lands well past the slack. Exit 1 on any breach,
exit 2 when a record file is missing/unreadable — both fail the CI
leg, with per-check PASS/FAIL lines for the log.
"""
import argparse
import json
import os
import sys
from typing import Dict, List, Optional

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def parse_derived(derived: str) -> Dict[str, str]:
    """Split a ``k1=v1;k2=v2`` derived string into a dict."""
    out: Dict[str, str] = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def derived_float(row: Dict, key: str) -> Optional[float]:
    """Numeric value of ``key`` in a row's derived string (``8.77x``
    and plain ``8.77`` both parse); None when absent/unparsable."""
    val = parse_derived(row.get("derived", "")).get(key)
    if val is None:
        return None
    try:
        return float(val.rstrip("x"))
    except ValueError:
        return None


class Gate:
    """Collects PASS/FAIL lines; any FAIL makes the run exit 1."""

    def __init__(self) -> None:
        self.failures = 0
        self.checks = 0

    def check(self, name: str, value: Optional[float], op: str,
              limit: float) -> None:
        self.checks += 1
        if value is None:
            self.failures += 1
            print(f"check_perf: FAIL {name}: value missing "
                  f"(wanted {op} {limit})")
            return
        ok = value <= limit if op == "<=" else value >= limit
        status = "PASS" if ok else "FAIL"
        if not ok:
            self.failures += 1
        print(f"check_perf: {status} {name}: {value:g} {op} {limit:g}")


def load(path: str) -> Dict:
    with open(path, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    if not isinstance(doc, dict):
        raise ValueError(f"{path}: expected a JSON object")
    return doc


def check_search(rows: Dict[str, Dict], g: Gate) -> None:
    """Search-path floors: engine speed, speedups, telemetry overhead."""
    sus = rows.get("bench_search.scoring_engine_sustained", {})
    g.check("scoring_engine_sustained.us_per_call",
            sus.get("us_per_call"), "<=", 1.0)
    e2e = rows.get("bench_search.e2e_resnet18_transform_refine", {})
    g.check("e2e_resnet18_transform_refine.speedup",
            derived_float(e2e, "speedup"), ">=", 3.0)
    bat = rows.get("bench_search.scoring_engine_sustained_batched", {})
    g.check("scoring_engine_sustained_batched.speedup_vs_prev",
            derived_float(bat, "speedup_vs_prev"), ">=", 5.0)
    obs = rows.get("bench_search.obs_overhead_sustained", {})
    g.check("obs_overhead_sustained.ratio",
            derived_float(obs, "ratio"), "<=", 1.10)


def check_serve(doc: Dict, g: Gate) -> None:
    """Serve-path ceilings/floors plus stage-breakdown consistency."""
    phases = doc.get("phases") or {}
    g.check("memo_c4.p99_ms",
            (phases.get("memo_c4") or {}).get("p99_ms"), "<=", 5.0)
    g.check("journal_c2.p99_ms",
            (phases.get("journal_c2") or {}).get("p99_ms"), "<=", 100.0)
    storm = doc.get("http_storm") or {}
    g.check("http_storm.shed_p99_ms",
            storm.get("shed_p99_ms"), "<=", 100.0)
    rates = doc.get("rates") or {}
    g.check("rates.memo_hit_rate",
            rates.get("memo_hit_rate"), ">=", 0.4)
    g.check("rates.journal_hit_rate",
            rates.get("journal_hit_rate"), ">=", 0.99)
    sb = doc.get("stage_breakdown") or {}
    g.check("stage_breakdown.n", sb.get("n"), ">=", 1)
    g.check("stage_breakdown.evaluate_ms",
            sb.get("evaluate_ms"), ">=", 0.001)
    # the stage identity survives aggregation: the mean stage times
    # must sum to the mean total (each record satisfies it exactly)
    if all(k in sb for k in ("admit_wait_ms", "evaluate_ms",
                             "respond_ms", "total_ms")):
        drift = abs(sb["admit_wait_ms"] + sb["evaluate_ms"]
                    + sb["respond_ms"] - sb["total_ms"])
        g.check("stage_breakdown.identity_drift_ms", drift, "<=",
                max(0.01, 0.01 * sb["total_ms"]))
    else:
        g.check("stage_breakdown.identity_drift_ms", None, "<=", 0.01)


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--search", default=os.path.join(
        REPO, "BENCH_search.json"),
        help="committed search benchmark record")
    p.add_argument("--serve", default=os.path.join(
        REPO, "BENCH_serve.json"),
        help="committed serve benchmark record")
    args = p.parse_args()

    g = Gate()
    failed_load = False
    for path, fn, pick in ((args.search, check_search,
                            lambda d: d.get("rows") or {}),
                           (args.serve, check_serve, lambda d: d)):
        try:
            doc = load(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"check_perf: FAIL cannot read {path}: {e}")
            failed_load = True
            continue
        fn(pick(doc), g)
    if failed_load:
        return 2
    if g.failures:
        print(f"check_perf: {g.failures}/{g.checks} checks FAILED")
        return 1
    print(f"check_perf: OK ({g.checks} checks)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
