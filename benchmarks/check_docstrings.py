"""Docstring-coverage gate for the public DSE + serve API.

Walks the public surface of the ``repro.dse`` and ``repro.serve``
module trees — module docstrings, public module-level functions and
classes, and public methods/properties defined on those classes — and
fails (exit 1) listing every name without a docstring. Wired into CI
and mirrored as a tier-1 test (``tests/test_docstrings.py``), so the
API reference cannot silently rot: a new public name ships with its
contract or not at all.

Run directly from the repo root::

    PYTHONPATH=src python benchmarks/check_docstrings.py
"""
from __future__ import annotations

import importlib
import inspect
import os
import sys
from typing import List

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

#: the documented surface — every module here must be fully covered
MODULES = [
    "repro.obs",
    "repro.obs.metrics",
    "repro.obs.report",
    "repro.obs.trace",
    "repro.dse",
    "repro.dse.driver",
    "repro.dse.explore",
    "repro.dse.pareto",
    "repro.dse.persist",
    "repro.dse.report",
    "repro.dse.space",
    "repro.dse.distrib",
    "repro.dse.distrib.coordinator",
    "repro.dse.distrib.lease",
    "repro.dse.distrib.worker",
    "repro.workloads",
    "repro.workloads.lowering",
    "repro.workloads.scenarios",
    "repro.serve",
    "repro.serve.engine",
    "repro.serve.jobs",
    "repro.serve.service",
    "repro.serve.transport",
]


def _class_members(cls) -> List[tuple]:
    """(name, needs-doc object) pairs for members *defined on* ``cls``
    (inherited members are the parent's responsibility)."""
    out = []
    for name, obj in vars(cls).items():
        if name.startswith("_"):
            continue
        if isinstance(obj, (staticmethod, classmethod)):
            obj = obj.__func__
        if inspect.isfunction(obj) or isinstance(obj, property):
            out.append((name, obj))
    return out


def missing_docstrings(module_names: List[str] = MODULES) -> List[str]:
    """Fully-qualified public names lacking a docstring."""
    missing: List[str] = []
    for mod_name in module_names:
        mod = importlib.import_module(mod_name)
        if not inspect.getdoc(mod):
            missing.append(mod_name + " (module)")
        for name, obj in vars(mod).items():
            if name.startswith("_"):
                continue
            if not (inspect.isfunction(obj) or inspect.isclass(obj)):
                continue
            if getattr(obj, "__module__", None) != mod_name:
                continue  # re-export; documented where it is defined
            qual = f"{mod_name}.{name}"
            if not inspect.getdoc(obj):
                missing.append(qual)
            if inspect.isclass(obj):
                for mname, mobj in _class_members(obj):
                    if not inspect.getdoc(mobj):
                        missing.append(f"{qual}.{mname}")
    return missing


def main() -> int:
    """CLI entry: print coverage, list gaps, exit 1 on any."""
    gaps = missing_docstrings()
    if gaps:
        print(f"docstring coverage: {len(gaps)} public names lack "
              "docstrings:")
        for g in gaps:
            print(f"  - {g}")
        return 1
    print(f"docstring coverage: OK ({len(MODULES)} modules, no gaps)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
