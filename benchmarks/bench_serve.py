"""Mapping-service latency/throughput benchmark (``BENCH_serve.json``).

Drives the serving stack the way a deployment client would — distinct
cold requests, hot repeats, and a warm restart — and commits the
client-observed numbers so the serving-path trajectory is tracked
across PRs the same way ``BENCH_search.json`` tracks the search path:

* ``cold_c1``    — N distinct requests (fresh journal), one client:
  every request runs a real sweep; the baseline cost of an answer.
* ``memo_c4``    — the same requests twice over, four concurrent
  clients: all served from the response memo (the hot-path regime the
  coalescing/memo layers exist for).
* ``journal_c2`` — a *new* service instance over the same journal
  path, two concurrent clients: each request re-proposes its points
  and serves them all from the journal with zero new mapping searches
  (the warm-restart regime).
* ``http_c4``    — the same traffic over the real HTTP transport
  (``repro.serve.transport``, loopback socket, four urllib clients):
  distinct requests answered from the warm journal, repeats from the
  memo — the delta against the in-process phases is what the wire
  costs.
* ``http_storm`` — a burst of distinct cold requests against a server
  with one sweep worker and a tiny admission cap (``max_pending=2``):
  some answer 200, the overflow answers 429 immediately — the
  load-shed regime; the recorded ``shed_rate`` proves admission
  control engages instead of queueing unboundedly.

Latency percentiles are client-side (submit-to-response, sorted-sample
p50/p99), so they include queueing — what a caller actually waits.
Sweeps run over a 4-point restricted ``dram_pim`` space with tiny
per-point search budgets (the ``tests/test_serve_service.py`` scale);
the numbers track the *serving machinery*, not paper-scale search.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Tuple

from repro.dse import ParamSpace
from repro.serve import (MappingHTTPServer, MappingRequest,
                         MappingResponse, MappingService)

from . import record
from .common import csv_row

BENCH_SERVE_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")

#: distinct cold requests per run (seeds 1..N — the seed enters the
#: journal content key, so each is a genuinely new sweep)
N_REQUESTS = 6


def _bench_space() -> ParamSpace:
    """Restricted 4-point ``dram_pim`` space: one sweep costs four
    fast-loop mapping searches, not a paper-scale budget."""
    return ParamSpace(
        family="dram_pim",
        axes={"channels_per_layer": (1, 2),
              "banks_per_channel": (2, 4),
              "columns_per_bank": (64, 128)},
        constraints=[lambda p: p["channels_per_layer"]
                     * p["banks_per_channel"] <= 4],
        defaults={"channels_per_layer": 2, "banks_per_channel": 2,
                  "columns_per_bank": 64},
    )


def _requests(n: int) -> List[MappingRequest]:
    return [MappingRequest(network="resnet18", explorer="grid", budget=4,
                           seed=s, n_candidates=3, max_steps=256)
            for s in range(1, n + 1)]


def _service(journal: str, max_workers: int = 1) -> MappingService:
    return MappingService(journal_path=journal, max_workers=max_workers,
                          space_overrides={"dram_pim": _bench_space()})


def _drive(svc: MappingService, reqs: List[MappingRequest],
           concurrency: int) -> Tuple[List, List[float], float]:
    """Fire ``reqs`` at the service from ``concurrency`` client threads;
    returns (responses, per-request client latencies, phase wall)."""
    out: List = [None] * len(reqs)
    lat = [0.0] * len(reqs)

    def one(i: int) -> None:
        t0 = time.perf_counter()
        out[i] = svc.request(reqs[i])
        lat[i] = time.perf_counter() - t0

    t0 = time.perf_counter()
    if concurrency <= 1:
        for i in range(len(reqs)):
            one(i)
    else:
        with ThreadPoolExecutor(max_workers=concurrency) as pool:
            list(pool.map(one, range(len(reqs))))
    return out, lat, time.perf_counter() - t0


def _http_post(url: str, req: MappingRequest,
               timeout: float = 300.0) -> Tuple[int, Dict]:
    """POST one request to a running server; returns (status, body) —
    non-2xx bodies included, so callers count sheds without raising."""
    import urllib.error
    import urllib.request
    r = urllib.request.Request(
        url + "/v1/mapping",
        data=json.dumps(req.to_dict()).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(r, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read() or b"{}")


def _drive_http(url: str, reqs: List[MappingRequest],
                concurrency: int) -> Tuple[List[int], List[Dict],
                                           List[float], float]:
    """HTTP twin of ``_drive``: fire ``reqs`` at a server from
    ``concurrency`` urllib clients; returns (status codes, response
    bodies, client latencies, phase wall)."""
    codes = [0] * len(reqs)
    out: List[Dict] = [{} for _ in reqs]
    lat = [0.0] * len(reqs)

    def one(i: int) -> None:
        t0 = time.perf_counter()
        codes[i], out[i] = _http_post(url, reqs[i])
        lat[i] = time.perf_counter() - t0

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=concurrency) as pool:
        list(pool.map(one, range(len(reqs))))
    return codes, out, lat, time.perf_counter() - t0


def _pct(lat: List[float], q: float) -> float:
    s = sorted(lat)
    return s[min(len(s) - 1, int(q * len(s)))]


def _phase(out: List, lat: List[float], wall: float) -> Dict:
    served: Dict[str, int] = {}
    for r in out:
        served[r.served_from] = served.get(r.served_from, 0) + 1
    # memo hits replay the original response (whose evaluated/proposed
    # describe the *first* sweep); only non-memo responses did work now
    fresh = [r for r in out if r.served_from != "memo"]
    return {
        "n": len(out),
        "wall_s": round(wall, 4),
        "rps": round(len(out) / wall, 2),
        "p50_ms": round(_pct(lat, 0.50) * 1e3, 3),
        "p99_ms": round(_pct(lat, 0.99) * 1e3, 3),
        "evaluated": sum(r.evaluated for r in fresh),
        "from_journal": sum(r.from_journal for r in fresh),
        "proposed": sum(r.proposed for r in fresh),
        "served_from": dict(sorted(served.items())),
    }


def serve_latency():
    """The three serving phases; rows mirror into BENCH_search.json,
    the full phase dicts into the committed BENCH_serve.json."""
    root = tempfile.mkdtemp(prefix="bench-serve-")
    journal = os.path.join(root, "service.jsonl")
    reqs = _requests(N_REQUESTS)
    phases: Dict[str, Dict] = {}
    try:
        svc = _service(journal)
        try:
            out, lat, wall = _drive(svc, reqs, concurrency=1)
            phases["cold_c1"] = _phase(out, lat, wall)
            out, lat, wall = _drive(svc, reqs * 2, concurrency=4)
            phases["memo_c4"] = _phase(out, lat, wall)
            stats = dict(svc.stats)
        finally:
            svc.close()
        # flight-recorder stage breakdown of the cold sweeps: where a
        # fresh request's wall clock went (admit-wait vs evaluate vs
        # respond) — read after close() so every done-callback has run
        fresh_recs = [r for r in svc.flight.snapshot()
                      if r["served_from"] == "search"]
        n_f = max(1, len(fresh_recs))
        stage_breakdown = {
            "n": len(fresh_recs),
            "admit_wait_ms": round(sum(
                r["admit_wait_s"] for r in fresh_recs) / n_f * 1e3, 3),
            "evaluate_ms": round(sum(
                r["evaluate_s"] for r in fresh_recs) / n_f * 1e3, 3),
            "respond_ms": round(sum(
                r["respond_s"] for r in fresh_recs) / n_f * 1e3, 3),
            "total_ms": round(sum(
                r["total_s"] for r in fresh_recs) / n_f * 1e3, 3),
        }
        # warm restart: a fresh instance over the same journal path
        svc2 = _service(journal, max_workers=2)
        try:
            out, lat, wall = _drive(svc2, reqs, concurrency=2)
            phases["journal_c2"] = _phase(out, lat, wall)
        finally:
            svc2.close()
        # the same traffic over the real transport: distinct requests
        # hit the warm journal, repeats the fresh server's memo — the
        # delta against the in-process phases is the wire cost
        server = MappingHTTPServer(_service(journal, max_workers=2),
                                   port=0).start()
        try:
            codes, bodies, lat, wall = _drive_http(
                server.url, reqs + reqs, concurrency=4)
            assert all(c == 200 for c in codes), codes
            phases["http_c4"] = _phase(
                [MappingResponse.from_dict(b) for b in bodies], lat, wall)
        finally:
            server.close()
        # request storm against one sweep worker and a 2-deep admission
        # queue: overflow answers 429 immediately instead of queueing
        storm_svc = MappingService(
            journal_path=os.path.join(root, "storm.jsonl"),
            max_workers=1, max_pending=2,
            space_overrides={"dram_pim": _bench_space()})
        server = MappingHTTPServer(storm_svc, port=0).start()
        try:
            storm_reqs = [MappingRequest(
                network="resnet18", explorer="grid", budget=4, seed=s,
                n_candidates=3, max_steps=256)
                for s in range(100, 100 + 2 * N_REQUESTS)]
            codes, _bodies, lat, wall = _drive_http(
                server.url, storm_reqs, concurrency=8)
            n_ok = sum(1 for c in codes if c == 200)
            n_shed = sum(1 for c in codes if c == 429)
            assert n_ok + n_shed == len(storm_reqs), codes
            storm = {
                "n": len(storm_reqs),
                "concurrency": 8,
                "max_workers": 1,
                "max_pending": 2,
                "ok": n_ok,
                "shed": n_shed,
                "shed_rate": round(n_shed / len(storm_reqs), 4),
                "wall_s": round(wall, 4),
                "rps": round(len(storm_reqs) / wall, 2),
                "p50_ms": round(_pct(lat, 0.50) * 1e3, 3),
                "p99_ms": round(_pct(lat, 0.99) * 1e3, 3),
                "shed_p99_ms": round(_pct(
                    [l for l, c in zip(lat, codes) if c == 429] or [0.0],
                    0.99) * 1e3, 3),
            }
        finally:
            server.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)

    total = sum(p["n"] for p in phases.values())
    memo_served = sum(p["served_from"].get("memo", 0)
                      for p in phases.values())
    jp = phases["journal_c2"]
    doc = {
        "schema": 1,
        "request": {"network": "resnet18", "explorer": "grid",
                    "budget": 4, "n_candidates": 3, "max_steps": 256,
                    "space": "dram_pim restricted (4 points)",
                    "distinct_requests": N_REQUESTS},
        "phases": phases,
        "stage_breakdown": stage_breakdown,
        "http_storm": storm,
        "rates": {
            "memo_hit_rate": round(memo_served / total, 4),
            "journal_hit_rate": round(
                jp["from_journal"] / max(1, jp["proposed"]), 4),
        },
        "service_stats": stats,
    }
    tmp = BENCH_SERVE_JSON + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, BENCH_SERVE_JSON)

    for name in sorted(phases):
        p = phases[name]
        derived = (f"p50_ms={p['p50_ms']};p99_ms={p['p99_ms']}"
                   f";rps={p['rps']};evaluated={p['evaluated']}"
                   f";served_from=" + "/".join(
                       f"{k}:{v}" for k, v in p["served_from"].items()))
        record.update_rows({f"bench_serve.{name}": {
            "us_per_call": round(p["p50_ms"] * 1e3, 3),
            "derived": derived}})
        yield csv_row(f"bench_serve.{name}", p["p50_ms"] * 1e3, derived)
    storm_derived = (f"shed_rate={storm['shed_rate']};ok={storm['ok']}"
                     f";shed={storm['shed']};rps={storm['rps']}"
                     f";shed_p99_ms={storm['shed_p99_ms']}")
    record.update_rows({"bench_serve.http_storm": {
        "us_per_call": round(storm["p50_ms"] * 1e3, 3),
        "derived": storm_derived}})
    yield csv_row("bench_serve.http_storm", storm["p50_ms"] * 1e3,
                  storm_derived)
    sb = stage_breakdown
    sb_derived = (f"admit_wait_ms={sb['admit_wait_ms']}"
                  f";evaluate_ms={sb['evaluate_ms']}"
                  f";respond_ms={sb['respond_ms']}"
                  f";total_ms={sb['total_ms']};n={sb['n']}")
    record.update_rows({"bench_serve.stage_breakdown": {
        "us_per_call": round(sb["evaluate_ms"] * 1e3, 3),
        "derived": sb_derived}})
    yield csv_row("bench_serve.stage_breakdown", sb["evaluate_ms"] * 1e3,
                  sb_derived)
    yield csv_row("bench_serve.rates", 0.0,
                  f"memo_hit_rate={doc['rates']['memo_hit_rate']}"
                  f";journal_hit_rate={doc['rates']['journal_hit_rate']}"
                  f";json={os.path.basename(BENCH_SERVE_JSON)}")
