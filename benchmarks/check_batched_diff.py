"""Batched-scorer differential smoke: engine vs reference byte-equality.

Runs ``optimize_network`` twice per configuration — once through the
batched ``OverlapEngine`` and once through the scalar reference path
(``use_engine=False``) — over a small strategy x mode x objective matrix
on resnet18, and fails (exit 1) on any divergence in ``total_ns`` or the
chosen mappings. This is the CI-sized version of the bit-identity
contract (DESIGN.md §6); the full differential suite lives in
``tests/test_batched_scoring.py``.
"""
import sys
import time

from repro.core import SearchConfig, describe, dram_pim
from repro.core.search import _optimize_network_reference
from repro.core.engine import OverlapEngine, optimize_network_engine

MATRIX = [
    ("overlap", "forward", "latency"),
    ("overlap", "backward", "edp"),
    ("transform", "forward", "edp"),
    ("transform", "middle_output", "latency"),
]


def main() -> int:
    desc = describe("resnet18")
    arch = dram_pim(2, 2, 4)
    ok = True
    for mode, strategy, objective in MATRIX:
        cfg = SearchConfig(mode=mode, strategy=strategy,
                           objective=objective, n_candidates=4, seed=7,
                           max_steps=1024)
        t0 = time.perf_counter()
        ref = _optimize_network_reference(desc.layers, desc.edges, arch,
                                          cfg)
        t1 = time.perf_counter()
        got = optimize_network_engine(desc.layers, desc.edges, arch, cfg,
                                      engine=OverlapEngine())
        t2 = time.perf_counter()
        same = (ref.total_ns == got.total_ns
                and all(a.mapping.cache_key == b.mapping.cache_key
                        and a.end_ns == b.end_ns
                        for a, b in zip(ref.layers, got.layers)))
        ok &= same
        print(f"{mode:9s} {strategy:13s} {objective:7s} "
              f"ref={t1 - t0:5.1f}s eng={t2 - t1:5.1f}s "
              f"{'EQUAL' if same else 'DIVERGED'}")
        if not same:
            print(f"  ref total_ns={ref.total_ns!r} "
                  f"eng total_ns={got.total_ns!r}")
    print("batched-scorer differential:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
