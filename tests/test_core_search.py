"""Whole-network search: modes, strategies, chain evaluation, BERT edges."""
import dataclasses

import numpy as np
import pytest

from repro.core import (LayerSpec, SearchConfig, chain_edges, describe,
                        dram_pim, evaluate_chain, heuristic_mapping,
                        optimize_network, reram_pim)


def tiny_arch():
    return dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=64)


def tiny_net():
    return [
        LayerSpec("l0", K=8, C=4, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l1", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l2", K=16, C=8, P=4, Q=4, R=3, S=3, stride=2, pad=1),
    ]


def cfg(**kw):
    base = dict(n_candidates=12, seed=0, max_steps=512)
    base.update(kw)
    return SearchConfig(**base)


@pytest.mark.parametrize("mode", ["original", "overlap", "transform"])
def test_modes_run_and_order(mode):
    net = tiny_net()
    res = optimize_network(net, chain_edges(net), tiny_arch(),
                           cfg(mode=mode))
    assert res.total_ns > 0
    assert len(res.layers) == 3


def test_overlap_beats_original_on_fixed_mappings():
    """Same mappings evaluated with overlap must never be slower than
    sequential (the motivation experiment, Fig 4)."""
    net = tiny_net()
    arch = tiny_arch()
    maps = [heuristic_mapping(l, arch, 512) for l in net]
    seq = evaluate_chain(maps, chain_edges(net), "original")
    ovl = evaluate_chain(maps, chain_edges(net), "overlap")
    assert ovl.total_ns <= seq.total_ns + 1e-6


def test_search_modes_ordering():
    """Searching with overlap/transform objective should find mappings at
    least as good (in overlapped latency) as evaluating the sequential-best
    mappings with overlap (paper Fig 10 trend)."""
    net = tiny_net()
    arch = tiny_arch()
    edges = chain_edges(net)
    res_orig = optimize_network(net, edges, arch, cfg(mode="original"))
    best_orig_maps = [lr.mapping for lr in res_orig.layers]
    best_orig_overlap = evaluate_chain(best_orig_maps, edges, "overlap")
    res_transform = optimize_network(net, edges, arch,
                                     cfg(mode="transform"))
    assert res_transform.total_ns <= best_orig_overlap.total_ns * 1.05


@pytest.mark.parametrize("strategy",
                         ["forward", "backward", "middle_output",
                          "middle_overall"])
def test_strategies_run(strategy):
    net = tiny_net()
    res = optimize_network(net, chain_edges(net), tiny_arch(),
                           cfg(mode="transform", strategy=strategy))
    assert res.total_ns > 0


def test_reram_arch_runs():
    net = tiny_net()
    arch = reram_pim(tiles_per_layer=2, blocks_per_tile=2,
                     columns_per_block=64)
    res = optimize_network(net, chain_edges(net), arch, cfg())
    assert res.total_ns > 0


def test_bert_edges_and_search():
    desc = describe("bert_encoder", seq=16, d_model=8, heads=2, d_ff=16)
    assert len(desc.layers) == 8
    # qk depends on q(0) and k(1); av on qk(3) and v(2)
    assert {e.producer for e in desc.edges[3]} == {0, 1}
    assert {e.producer for e in desc.edges[4]} == {3, 2}
    res = optimize_network(desc.layers, desc.edges, tiny_arch(),
                           cfg(mode="transform"))
    assert res.total_ns > 0


def test_deterministic_given_seed():
    net = tiny_net()
    a = optimize_network(net, chain_edges(net), tiny_arch(), cfg())
    b = optimize_network(net, chain_edges(net), tiny_arch(), cfg())
    assert a.total_ns == b.total_ns


def test_chain_monotone_finish_times():
    net = tiny_net()
    arch = tiny_arch()
    maps = [heuristic_mapping(l, arch, 512) for l in net]
    res = evaluate_chain(maps, chain_edges(net), "overlap")
    for lr in res.layers:
        # finish times strictly increase along each bank's steps
        assert np.all(np.diff(lr.finish_ns, axis=1) > 0)


def test_refinement_never_worse():
    """Beyond-paper coordinate-descent refinement only accepts strict
    improvements of the whole-network objective."""
    net = tiny_net()
    base = optimize_network(net, chain_edges(net), tiny_arch(),
                            cfg(mode="transform"))
    ref = optimize_network(net, chain_edges(net), tiny_arch(),
                           cfg(mode="transform", refine_passes=1))
    assert ref.total_ns <= base.total_ns + 1e-6


def test_use_exhaustive_overlap_changes_code_path(monkeypatch):
    """SearchConfig.use_exhaustive_overlap routes the reference path's
    ready-step analysis through OverlaPIM's exhaustive traversal (it was
    once declared but never consulted — baseline comparisons silently ran
    the fast path)."""
    import repro.core.search as search_mod

    calls = {"exh": 0, "ana": 0}
    real_exh = search_mod.ready_steps_exhaustive
    real_ana = search_mod.ready_steps_analytical

    def count_exh(*a, **kw):
        calls["exh"] += 1
        return real_exh(*a, **kw)

    def count_ana(*a, **kw):
        calls["ana"] += 1
        return real_ana(*a, **kw)

    monkeypatch.setattr(search_mod, "ready_steps_exhaustive", count_exh)
    monkeypatch.setattr(search_mod, "ready_steps_analytical", count_ana)

    net = tiny_net()
    small = cfg(n_candidates=3, max_steps=64, mode="overlap")
    on = optimize_network(net, chain_edges(net), tiny_arch(),
                          dataclasses.replace(small,
                                              use_exhaustive_overlap=True))
    assert calls["exh"] > 0 and calls["ana"] == 0

    calls["exh"] = calls["ana"] = 0
    off = optimize_network(net, chain_edges(net), tiny_arch(),
                           dataclasses.replace(small, use_engine=False))
    assert calls["exh"] == 0 and calls["ana"] > 0
    # the exhaustive analysis is the oracle the analytical closed form
    # reproduces, so both flags pick the same mappings and timings
    assert on.total_ns == off.total_ns


def test_engine_rejects_exhaustive_overlap():
    from repro.core.engine import optimize_network_engine

    net = tiny_net()
    with pytest.raises(ValueError):
        optimize_network_engine(net, chain_edges(net), tiny_arch(),
                                cfg(use_exhaustive_overlap=True))
