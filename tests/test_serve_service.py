"""Mapping-service tests: request/response schemas, journal-as-cache,
request coalescing, deadlines, area budgets, and the job queue.

Sweeps run over a restricted ``dram_pim`` space (``space_overrides``)
with tiny per-point search budgets, mirroring ``tests/test_dse.py``'s
scale, so the whole module stays in the fast core loop. The serve
*LM* engine's compile-heavy paths live in ``test_train_substrate.py``
(slow-marked); the fast ``Engine._sample`` unit tests live here.
"""
import dataclasses
import json
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.dse import ParamSpace, RunJournal, run_dse
from repro.serve import (Job, JobQueue, MappingRequest, MappingResponse,
                         MappingService, QueueFull)
from repro.serve.engine import Engine, ServeConfig


def tiny_space() -> ParamSpace:
    return ParamSpace(
        family="dram_pim",
        axes={
            "channels_per_layer": (1, 2),
            "banks_per_channel": (2, 4),
            "columns_per_bank": (64, 128),
        },
        constraints=[
            lambda p: p["channels_per_layer"] * p["banks_per_channel"] <= 4,
        ],
        defaults={"channels_per_layer": 2, "banks_per_channel": 2,
                  "columns_per_bank": 64},
    )


def tiny_request(**kw) -> MappingRequest:
    base = dict(network="resnet18", mode="transform", explorer="grid",
                budget=4, n_candidates=3, max_steps=256, seed=0)
    base.update(kw)
    return MappingRequest(**base)


def make_service(**kw) -> MappingService:
    kw.setdefault("space_overrides", {"dram_pim": tiny_space()})
    return MappingService(**kw)


# ---------------------------------------------------------------------------
# Request/response schemas.
# ---------------------------------------------------------------------------

def test_request_roundtrip_and_cache_key():
    req = tiny_request(objective="edp", area_budget_mm2=10.0)
    again = MappingRequest.from_dict(req.to_dict())
    assert again == req
    assert again.cache_key() == req.cache_key()
    # any field change changes the identity
    assert tiny_request(budget=5).cache_key() != req.cache_key()
    assert tiny_request(objective="edp",
                        area_budget_mm2=10.0,
                        deadline_s=1.0).cache_key() != req.cache_key()


def test_request_rejects_unknown_fields_and_bad_values():
    with pytest.raises(ValueError):
        MappingRequest.from_dict({"network": "resnet18", "objectiv": "edp"})
    with pytest.raises(ValueError):
        tiny_request(deadline_s=-1.0)
    with pytest.raises(ValueError):
        tiny_request(deadline_s=1.0, distributed=2)
    with pytest.raises(AssertionError):
        tiny_request(mode="nope")


def test_response_json_roundtrips():
    svc = make_service()
    try:
        resp = svc.request(tiny_request())
    finally:
        svc.close()
    d = json.loads(resp.to_json())
    assert d["status"] == "ok"
    assert d["best"]["arch_name"] == resp.best["arch_name"]
    assert len(d["frontier_points"]) == len(resp.frontier_points)


# ---------------------------------------------------------------------------
# Journal-as-cache semantics.
# ---------------------------------------------------------------------------

def test_repeat_request_served_from_memo_then_journal(tmp_path):
    path = str(tmp_path / "service.jsonl")
    svc = make_service(journal_path=path)
    try:
        r1 = svc.request(tiny_request())
        assert r1.served_from == "search" and r1.evaluated == 4
        r2 = svc.request(tiny_request())
        assert r2.served_from == "memo"
        assert svc.stats["sweeps"] == 1      # memo answered without a sweep
        assert r2.frontier_json == r1.frontier_json
    finally:
        svc.close()
    # a fresh service on the same journal (restart): zero new searches
    svc2 = make_service(journal_path=path)
    try:
        r3 = svc2.request(tiny_request())
        assert r3.served_from == "journal"
        assert r3.evaluated == 0 and r3.from_journal == 4
        assert r3.frontier_json == r1.frontier_json   # byte-identical
    finally:
        svc2.close()


def test_bigger_budget_request_reuses_smaller_requests_points(tmp_path):
    svc = make_service(journal_path=str(tmp_path / "service.jsonl"))
    try:
        r1 = svc.request(tiny_request(budget=2))
        assert r1.evaluated == 2
        r2 = svc.request(tiny_request(budget=4))
        # grid order is deterministic: the first 2 points come from the
        # journal, only the 2 new ones are searched
        assert r2.from_journal == 2 and r2.evaluated == 2
    finally:
        svc.close()


def test_service_frontier_matches_direct_run_dse(tmp_path):
    svc = make_service(journal_path=str(tmp_path / "service.jsonl"))
    try:
        resp = svc.request(tiny_request())
    finally:
        svc.close()
    res = run_dse(tiny_request().dse_config(), space=tiny_space(),
                  journal=RunJournal())
    assert resp.frontier_json == res.frontier.canonical_json()


# ---------------------------------------------------------------------------
# Coalescing.
# ---------------------------------------------------------------------------

def test_concurrent_identical_requests_share_one_sweep():
    svc = make_service(max_workers=1)
    gate = threading.Event()
    blocker, _ = svc._queue.submit("blocker", gate.wait)
    try:
        req = tiny_request()
        j1 = svc.submit(req)       # queued behind the blocker
        j2 = svc.submit(req)       # identical + in flight => coalesced
        assert j2 is j1
        assert j1.n_attached == 2
        assert svc.stats["coalesced"] == 1
        gate.set()
        r1, r2 = j1.result(60), j2.result(60)
        assert r1 is r2
        assert svc.stats["sweeps"] == 1
        # after completion: answered by the memo, still one sweep
        r3 = svc.request(req)
        assert r3.served_from == "memo" and svc.stats["sweeps"] == 1
    finally:
        gate.set()
        blocker.result(60)
        svc.close()


def test_different_requests_do_not_coalesce():
    svc = make_service(max_workers=1)
    try:
        j1 = svc.submit(tiny_request(seed=0))
        j2 = svc.submit(tiny_request(seed=1))
        assert j1 is not j2
        j1.result(60), j2.result(60)
        assert svc.stats["sweeps"] == 2 and svc.stats["coalesced"] == 0
    finally:
        svc.close()


def test_job_queue_propagates_errors_and_tracks_inflight():
    q = JobQueue(max_workers=1)
    try:
        def boom():
            raise RuntimeError("no")
        job, coalesced = q.submit("k", boom)
        assert not coalesced
        with pytest.raises(RuntimeError, match="no"):
            job.result(10)
        assert job.status == "failed"
        # the key left the in-flight table: a resubmit runs fresh
        ok, coalesced = q.submit("k", lambda: 42)
        assert not coalesced
        assert ok is not job and ok.result(10) == 42
        assert q.inflight() == 0
        assert Job.completed("m", 7).result(0) == 7
    finally:
        q.shutdown()


# ---------------------------------------------------------------------------
# Deadlines (best-so-far answers).
# ---------------------------------------------------------------------------

def test_deadline_returns_best_so_far_and_converges(tmp_path):
    path = str(tmp_path / "service.jsonl")
    svc = make_service(journal_path=path)
    try:
        # deadline 0: the baseline is always scored, nothing more
        r = svc.request(tiny_request(deadline_s=0.0))
        assert r.deadline_hit and r.proposed == 1
        assert r.status == "ok" and r.best is not None
        assert r.best["arch_name"] == r.baseline["arch_name"]
    finally:
        svc.close()
    # warm journal: replaying the prefix is near-free, so repeated
    # deadline requests make monotone progress through the sweep (each
    # one spends its deadline on new points and lands at least one).
    # One LIVE service throughout: deadline-truncated answers must not
    # be memoized, or the service would freeze at the first cut.
    svc = make_service(journal_path=path)
    try:
        seen = 1
        for _ in range(8):
            r = svc.request(tiny_request(deadline_s=0.2))
            assert r.served_from != "memo"
            assert r.proposed >= seen
            seen = r.proposed
            if not r.deadline_hit:
                break
        assert not r.deadline_hit       # converged to the full budget
    finally:
        svc.close()
    # the full request now needs no deadline headroom at all
    svc = make_service(journal_path=path)
    try:
        full = svc.request(tiny_request())
        assert full.evaluated == 0 and full.from_journal == 4
    finally:
        svc.close()


def test_run_dse_deadline_stats_flag():
    res = run_dse(tiny_request().dse_config(), space=tiny_space(),
                  journal=RunJournal())
    assert res.stats["deadline_hit"] is False
    res = run_dse(tiny_request().dse_config(), space=tiny_space(),
                  journal=RunJournal(), deadline_s=0.0)
    assert res.stats["deadline_hit"] is True
    assert len(res.records) >= 1          # the baseline always lands


# ---------------------------------------------------------------------------
# Area budgets and mapping materialization.
# ---------------------------------------------------------------------------

def test_area_budget_constrains_winner():
    svc = make_service()
    try:
        free = svc.request(tiny_request())
        areas = sorted(p["area_mm2"] for p in free.frontier_points)
        cap = areas[0]
        capped = svc.request(tiny_request(area_budget_mm2=cap))
        assert capped.status == "ok"
        assert capped.best["area_mm2"] <= cap + 1e-12
        infeasible = svc.request(tiny_request(area_budget_mm2=cap * 0.01))
        assert infeasible.status == "infeasible"
        assert infeasible.best is None
        assert infeasible.frontier_points    # frontier still reported
    finally:
        svc.close()


def test_area_budget_winner_honors_search_objective():
    """Under an area budget the winner minimizes the *request's*
    objective (here EDP), not unconditionally latency."""
    svc = make_service()
    try:
        free = svc.request(tiny_request(objective="edp"))
        cap = max(p["area_mm2"] for p in free.frontier_points)
        capped = svc.request(tiny_request(objective="edp",
                                          area_budget_mm2=cap))
    finally:
        svc.close()
    # ground truth from a direct sweep: min objective_value in budget
    res = run_dse(tiny_request(objective="edp").dse_config(),
                  space=tiny_space(), journal=RunJournal())
    eligible = [r for r in res.records
                if r["area_mm2"] <= cap + 1e-12]
    want = min(eligible, key=lambda r: r["objective_value"])
    assert capped.best["point_key"] == want["point_key"]
    assert capped.best["objective_value"] == want["objective_value"]


def test_include_mapping_materializes_loop_nests():
    svc = make_service()
    try:
        resp = svc.request(tiny_request(include_mapping=True))
        assert resp.mapping and len(resp.mapping) == resp.best["n_layers"]
        for lay in resp.mapping:
            assert lay["nest"] and isinstance(lay["nest"], str)
            assert lay["latency_ns"] > 0
        total = sum(lay["energy_pj"] for lay in resp.mapping)
        assert total == pytest.approx(resp.best["energy_pj"])
    finally:
        svc.close()


def test_mapping_materialization_cached_per_winner(monkeypatch):
    """The winner's loop nests are searched once and cached by the
    winning record's content key — a second request with a different
    cache key but the same winner replays them without a new search."""
    calls = []
    orig = MappingService._materialize_mapping

    def counting(self, req, best):
        calls.append(best["key"])
        return orig(self, req, best)

    monkeypatch.setattr(MappingService, "_materialize_mapping", counting)
    svc = make_service()
    try:
        r1 = svc.request(tiny_request(include_mapping=True,
                                      deadline_s=300.0))
        assert not r1.deadline_hit and r1.mapping
        # different deadline => different cache key => memo miss, but
        # the journal-served sweep picks the same winner
        r2 = svc.request(tiny_request(include_mapping=True,
                                      deadline_s=301.0))
        assert r2.served_from == "journal"
        assert r2.mapping == r1.mapping
        assert len(calls) == 1
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Multi-tenant hardening: shared-state races, provenance accounting,
# objective ranking, shared-engine reuse, LRU/persistence, compaction.
# ---------------------------------------------------------------------------

def test_mixed_key_stress_under_concurrency(tmp_path):
    """max_workers=4 with a mix of repeated keys: the shared journal,
    memo, and nest cache are all mutated from concurrent workers, and
    every response must still be correct and byte-identical per key."""
    svc = make_service(journal_path=str(tmp_path / "svc.jsonl"),
                       max_workers=4)
    seeds = [0, 1, 2, 3]
    reqs = [tiny_request(seed=s, include_mapping=True)
            for s in seeds] * 3
    out = [None] * len(reqs)

    def one(i: int) -> None:
        out[i] = svc.request(reqs[i], timeout=600)

    threads = [threading.Thread(target=one, args=(i,))
               for i in range(len(reqs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.close()
    by_seed = {}
    for r, req in zip(out, reqs):
        assert r is not None and r.status == "ok" and r.mapping
        by_seed.setdefault(req.seed, []).append(r)
    for rs in by_seed.values():
        assert len({r.frontier_json for r in rs}) == 1
        assert len({json.dumps(r.mapping) for r in rs}) == 1
    # ground truth: an independent serial sweep per seed (the shared
    # engine and the concurrency must not perturb any answer)
    for seed in seeds:
        res = run_dse(tiny_request(seed=seed).dse_config(),
                      space=tiny_space(), journal=RunJournal())
        assert by_seed[seed][0].frontier_json \
            == res.frontier.canonical_json()


def test_provenance_counters_sum_to_requests():
    """Every arrival is accounted exactly once: the four served_from
    counters plus the shed counter partition serve.requests."""
    svc = make_service(max_pending=1)
    gate = threading.Event()
    blocker, _ = svc._queue.submit("blocker", lambda: gate.wait(60))
    try:
        while svc._queue.pending() != 0:
            pass
        req = tiny_request()
        j1 = svc.submit(req)              # -> search (fills the 1 slot)
        j2 = svc.submit(req)              # -> coalesced
        assert j2 is j1
        with pytest.raises(QueueFull):
            svc.submit(tiny_request(seed=9))   # -> shed
        gate.set()
        j1.result(120)
        r = svc.request(req)              # -> memo
        assert r.served_from == "memo"
    finally:
        gate.set()
        svc.close()
    c = svc.metrics_snapshot()["counters"]
    total = int(c.get("serve.requests", 0))
    assert total == 4
    provenance = sum(int(c.get(f"serve.served_from.{s}", 0))
                     for s in ("memo", "journal", "search", "coalesced"))
    assert provenance + int(c.get("serve.shed", 0)) == total
    assert svc.stats["shed"] == 1
    # coalesced waiters observe the latency histogram too: one sample
    # per arrival that got an answer (4 arrivals - 1 shed)
    hist = svc.metrics_snapshot()["histograms"]["serve.request_seconds"]
    assert hist["count"] == 3


def test_memo_replay_reports_zero_work():
    svc = make_service()
    try:
        r1 = svc.request(tiny_request())
        r2 = svc.request(tiny_request())
    finally:
        svc.close()
    assert r1.evaluated > 0 and r1.wall_s > 0
    # provenance describes THIS answer: a replay did no sweep work
    assert r2.served_from == "memo"
    assert (r2.evaluated, r2.from_journal, r2.wall_s) == (0, 0, 0.0)
    assert r2.frontier_json == r1.frontier_json
    assert r2.best == r1.best


def test_best_recomputes_objective_from_record_fields():
    """Ranking never trusts a stored objective_value: records missing
    it (pre-energy journal schema) must still rank under the request's
    objective, not silently fall back to latency."""
    svc = make_service()
    try:
        rec_fast = {"total_ns": 100.0, "energy_pj": 1000.0,
                    "area_mm2": 1.0}
        rec_low_edp = {"total_ns": 200.0, "energy_pj": 100.0,
                       "area_mm2": 1.0}
        res = SimpleNamespace(records=[rec_fast, rec_low_edp])
        assert svc._best(tiny_request(objective="edp"), res) \
            is rec_low_edp
        assert svc._best(tiny_request(objective="energy"), res) \
            is rec_low_edp
        assert svc._best(tiny_request(), res) is rec_fast
    finally:
        svc.close()


def test_pre_energy_schema_journal_ranks_correctly(tmp_path):
    """Regression: replay an EDP request against a journal whose
    records were written without objective_value/edp_ns_pj (the
    pre-energy schema) — the winner must match the modern answer."""
    path = str(tmp_path / "svc.jsonl")
    req = tiny_request(objective="edp")
    svc = make_service(journal_path=path)
    try:
        r1 = svc.request(req)
    finally:
        svc.close()
    stripped = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            d = json.loads(line)
            d.pop("objective_value", None)
            d.pop("edp_ns_pj", None)
            stripped.append(d)
    with open(path, "w", encoding="utf-8") as fh:
        for d in stripped:
            fh.write(json.dumps(d, sort_keys=True) + "\n")
    svc2 = make_service(journal_path=path)
    try:
        r2 = svc2.request(req)
    finally:
        svc2.close()
    assert r2.served_from == "journal" and r2.evaluated == 0
    assert r2.best["point_key"] == r1.best["point_key"]
    assert r2.frontier_json == r1.frontier_json


def test_shared_engine_warms_perf_cache_across_requests():
    """Two distinct same-family requests (different journal keys, same
    deterministic mapping candidates): the second starts with the
    first's PerfCache and arch bundles warm — nonzero cross-request
    hit rate — without perturbing its answer."""
    svc = make_service()
    try:
        svc.request(tiny_request())                     # latency
        perf = svc._engine._perf
        h1, m1 = perf.hits, perf.misses
        assert m1 > 0
        r2 = svc.request(tiny_request(objective="edp"))  # same family
        h2, m2 = perf.hits, perf.misses
        assert r2.evaluated > 0          # a real sweep, not a replay
        assert h2 > h1                   # warm hits across requests
        assert (m2 - m1) < m1            # far fewer cold analyses
        c = svc.metrics_snapshot()["counters"]
        assert int(c.get("engine.perf_hit", 0)) == h2
        assert int(c.get("engine.perf_miss", 0)) == m2
    finally:
        svc.close()
    # the shared engine is a cache, never an answer-changer
    res = run_dse(tiny_request(objective="edp").dse_config(),
                  space=tiny_space(), journal=RunJournal())
    assert r2.frontier_json == res.frontier.canonical_json()


def test_memo_lru_eviction_backstopped_by_journal():
    svc = make_service(memo_cap=2)
    try:
        svc.request(tiny_request(seed=0))
        svc.request(tiny_request(seed=1))
        svc.request(tiny_request(seed=2))     # evicts seed=0's memo
        r0 = svc.request(tiny_request(seed=0))
        assert r0.served_from == "journal"    # re-ran, all points warm
        assert r0.evaluated == 0
        r2 = svc.request(tiny_request(seed=2))
        assert r2.served_from == "memo"       # still resident
    finally:
        svc.close()


def test_persist_dir_restores_memo_and_nests(tmp_path):
    journal = str(tmp_path / "svc.jsonl")
    persist = str(tmp_path / "persist")
    req = tiny_request(include_mapping=True)
    svc = make_service(journal_path=journal, persist_dir=persist)
    try:
        r1 = svc.request(req)
        assert r1.served_from == "search" and r1.mapping
    finally:
        svc.close()
    # a restarted server answers from the reloaded memo: zero sweeps
    svc2 = make_service(journal_path=journal, persist_dir=persist)
    try:
        r2 = svc2.request(req)
        assert r2.served_from == "memo"
        assert svc2.stats["sweeps"] == 0
        assert r2.frontier_json == r1.frontier_json
        assert r2.mapping == r1.mapping
        # the nest cache came back too: a different-keyed request with
        # the same winner replays the nests without a mapping search
        calls = []
        orig = MappingService._materialize_mapping
        MappingService._materialize_mapping = \
            lambda self, rq, best: calls.append(1) or orig(self, rq, best)
        try:
            r3 = svc2.request(tiny_request(include_mapping=True,
                                           deadline_s=123.0))
        finally:
            MappingService._materialize_mapping = orig
        assert r3.mapping == r1.mapping and calls == []
    finally:
        svc2.close()


def test_compact_rewrites_persisted_caches_and_journal(tmp_path):
    journal = str(tmp_path / "svc.jsonl")
    persist = str(tmp_path / "persist")
    svc = make_service(journal_path=journal, persist_dir=persist,
                       memo_cap=1)
    try:
        svc.request(tiny_request(seed=0))
        svc.request(tiny_request(seed=1))   # evicts seed=0 from memo
        memo_file = str(tmp_path / "persist" / "memo.jsonl")
        with open(memo_file) as fh:
            assert len(fh.read().splitlines()) == 2   # write-through
        svc.compact()
        with open(memo_file) as fh:
            lines = fh.read().splitlines()
        assert len(lines) == 1              # evicted entry dropped
        assert json.loads(lines[0])["key"] \
            == tiny_request(seed=1).cache_key()
        assert svc.metrics_snapshot()["counters"]["serve.compactions"] \
            == 1
    finally:
        svc.close()


def test_background_compaction_cadence(tmp_path):
    svc = make_service(journal_path=str(tmp_path / "svc.jsonl"),
                       persist_dir=str(tmp_path / "persist"),
                       compact_every_s=0.05)
    try:
        svc.request(tiny_request())
        deadline = time.time() + 30
        while time.time() < deadline:
            c = svc.metrics_snapshot()["counters"]
            if c.get("serve.compactions", 0) >= 2:
                break
            time.sleep(0.02)
        assert c.get("serve.compactions", 0) >= 2
    finally:
        svc.close()
    # close() stopped the maintenance thread
    assert svc._compactor is None


def test_response_from_dict_rejects_unknown_fields():
    svc = make_service()
    try:
        resp = svc.request(tiny_request())
    finally:
        svc.close()
    again = MappingResponse.from_dict(resp.to_dict())
    assert again == resp
    bad = resp.to_dict()
    bad["extra"] = 1
    with pytest.raises(ValueError, match="extra"):
        MappingResponse.from_dict(bad)


# ---------------------------------------------------------------------------
# Flight recorder + sliding windows: stage accounting, slow retention,
# scrape-time gauges, and the determinism pin.
# ---------------------------------------------------------------------------

def test_flight_records_every_request_path():
    """memo / search / coalesced / shed all leave a flight record with
    the right provenance, and fresh-job stage timings satisfy the
    identity admit + evaluate + respond == total."""
    svc = make_service(max_pending=1)
    gate = threading.Event()
    blocker, _ = svc._queue.submit("blocker", lambda: gate.wait(60))
    try:
        while svc._queue.pending() != 0:
            pass
        req = tiny_request()
        j1 = svc.submit(req)                       # -> search
        j2 = svc.submit(req)                       # -> coalesced
        assert j2 is j1
        with pytest.raises(QueueFull):
            svc.submit(tiny_request(seed=9))       # -> shed
        gate.set()
        j1.result(120)
        svc.request(req)                           # -> memo
    finally:
        gate.set()
        svc.close()
    recs = svc.flight.snapshot()
    by_src = {r["served_from"]: r for r in recs}
    assert set(by_src) == {"search", "coalesced", "shed", "memo"}
    assert by_src["shed"]["outcome"] == "shed"
    search = by_src["search"]
    assert search["outcome"] == "ok" and search["evaluated"] == 4
    for stage in ("admit_wait_s", "evaluate_s", "respond_s"):
        assert search[stage] >= 0.0
    assert search["admit_wait_s"] + search["evaluate_s"] \
        + search["respond_s"] == pytest.approx(search["total_s"])
    # the blocker held the single worker: the search request's admit
    # wait is real, not epsilon
    assert search["admit_wait_s"] > 0.0
    # memo/coalesced did no evaluate work
    assert by_src["memo"]["evaluate_s"] == 0.0
    assert by_src["coalesced"]["evaluate_s"] == 0.0
    json.dumps(recs)                               # JSON-safe


def test_flight_stage_sum_matches_request_seconds_histogram():
    """Acceptance: a fresh request's admit_wait + evaluate equals the
    serve.request_seconds observation for it, up to the respond-stage
    epsilon (the histogram observes at the end of the evaluate stage;
    t_finish lands after the respond hop)."""
    svc = make_service()
    try:
        svc.request(tiny_request())
    finally:
        svc.close()
    [rec] = [r for r in svc.flight.snapshot()
             if r["served_from"] == "search"]
    hist = svc.metrics_snapshot()["histograms"]["serve.request_seconds"]
    assert hist["count"] == 1
    stage_sum = rec["admit_wait_s"] + rec["evaluate_s"]
    # observed value == sum of observations for a single request
    assert abs(hist["sum"] - stage_sum) \
        <= rec["respond_s"] + 0.05 * hist["sum"] + 0.005


def test_flight_slow_request_keeps_full_detail():
    """slow_threshold_s=0 marks every request slow: the slow ring keeps
    the request dict, sweep summary and the engine stats delta."""
    svc = make_service(slow_threshold_s=0.0)
    try:
        r1 = svc.request(tiny_request())
    finally:
        svc.close()
    full = svc.flight.get(r1.request_key[:10])   # prefix lookup
    assert full is not None and full["slow"]
    assert full["request"]["network"] == "resnet18"
    assert full["summary"] and full["frontier_size"] \
        == len(r1.frontier_points)
    delta = full["engine_delta"]
    assert delta and all(isinstance(v, int) for v in delta.values())
    assert delta.get("score_miss", 0) > 0        # the sweep's own work


def test_flight_disabled_and_windows_disabled():
    svc = make_service(flight_cap=0, window_s=0)
    try:
        svc.request(tiny_request())
        snap = svc.metrics_snapshot()
    finally:
        svc.close()
    assert not svc.flight.enabled
    assert "flight" not in snap
    assert "serve.request_seconds.window.p50" not in snap["gauges"]


def test_window_gauges_and_slo_published_at_scrape():
    svc = make_service(slo_target_s=0.001)   # everything breaches
    try:
        svc.request(tiny_request())
        svc.request(tiny_request())          # memo: sub-ms, ok
        snap = svc.metrics_snapshot()
    finally:
        svc.close()
    g, c = snap["gauges"], snap["counters"]
    assert g["serve.request_seconds.window.count"] == 2.0
    assert g["serve.request_seconds.window.p99"] \
        >= g["serve.request_seconds.window.p50"] >= 0.0
    assert g["serve.slo.target_s"] == pytest.approx(0.001)
    assert int(c["serve.slo.breach"]) == 1   # the real sweep
    assert int(c["serve.slo.ok"]) == 1       # the memo replay
    assert g["serve.slo.burn_rate"] > 0.0
    # the snapshot renders through both surfaces without error
    from repro.obs import render_prometheus, render_report
    assert "flight recorder" in render_report(snap)
    assert "repro_serve_slo_burn_rate" in render_prometheus(snap)


def test_frontier_identical_with_flight_and_windows_toggled(tmp_path):
    """Determinism pin (DESIGN.md Sections 12/14): the flight recorder
    and the windows observe, never steer — the canonical frontier JSON
    is byte-identical with them on, off, or in slow-everything mode."""
    base = make_service(flight_cap=0, window_s=0)
    try:
        r_off = base.request(tiny_request())
    finally:
        base.close()
    on = make_service(flight_cap=8, slow_threshold_s=0.0,
                      window_s=30.0, slo_target_s=0.5)
    try:
        r_on = on.request(tiny_request())
    finally:
        on.close()
    assert r_on.frontier_json == r_off.frontier_json

    def strip_wall(d):
        return {k: v for k, v in d.items() if k != "wall_s"}

    # everything but the (inherently nondeterministic) wall clock
    assert strip_wall(r_on.best) == strip_wall(r_off.best)
    assert [strip_wall(p) for p in r_on.frontier_points] \
        == [strip_wall(p) for p in r_off.frontier_points]
    assert len(on.flight) == 1 and len(base.flight) == 0


def test_jobs_stage_timestamps_are_telemetry_only():
    """The queue stamps t_submit/t_eval_start/t_eval_end/t_finish in
    stage order; a pre-completed job only has t_finish."""
    q = JobQueue(max_workers=1)
    try:
        job, _ = q.submit("k", lambda: 41)
        assert job.result(10) == 41
        while job.t_finish is None:
            time.sleep(0.001)
        assert job.t_submit <= job.t_eval_start <= job.t_eval_end \
            <= job.t_finish
    finally:
        q.shutdown()
    done = Job.completed("m", 7)
    assert done.t_finish is not None and done.t_submit is None


# ---------------------------------------------------------------------------
# Serve LM engine: the fast (non-compiling) sampling paths.
# ---------------------------------------------------------------------------

def _bare_engine(**scfg) -> Engine:
    # _sample needs only the config — skip __init__'s jit/model setup
    eng = object.__new__(Engine)
    eng.scfg = ServeConfig(**scfg)
    return eng


def test_engine_sample_greedy_is_argmax():
    eng = _bare_engine(temperature=0.0)
    logits = np.array([[0.1, 2.0, -1.0], [3.0, 0.0, 1.0]], np.float32)
    out = np.asarray(eng._sample(logits, None))
    np.testing.assert_array_equal(out, [1, 0])
    assert out.dtype == np.int32


def test_engine_sample_temperature_seeded_and_in_vocab():
    import jax
    eng = _bare_engine(temperature=0.7)
    logits = np.array([[0.5, 1.5, 0.0, -2.0]] * 8, np.float32)
    a = np.asarray(eng._sample(logits, jax.random.PRNGKey(0)))
    b = np.asarray(eng._sample(logits, jax.random.PRNGKey(0)))
    np.testing.assert_array_equal(a, b)      # deterministic in the key
    assert ((a >= 0) & (a < 4)).all()
    # low temperature concentrates on the argmax
    cold = np.asarray(_bare_engine(temperature=1e-4)._sample(
        logits, jax.random.PRNGKey(1)))
    np.testing.assert_array_equal(cold, np.ones_like(cold))
