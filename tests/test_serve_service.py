"""Mapping-service tests: request/response schemas, journal-as-cache,
request coalescing, deadlines, area budgets, and the job queue.

Sweeps run over a restricted ``dram_pim`` space (``space_overrides``)
with tiny per-point search budgets, mirroring ``tests/test_dse.py``'s
scale, so the whole module stays in the fast core loop. The serve
*LM* engine's compile-heavy paths live in ``test_train_substrate.py``
(slow-marked); the fast ``Engine._sample`` unit tests live here.
"""
import dataclasses
import json
import threading
import time

import numpy as np
import pytest

from repro.dse import ParamSpace, RunJournal, run_dse
from repro.serve import (Job, JobQueue, MappingRequest, MappingResponse,
                         MappingService)
from repro.serve.engine import Engine, ServeConfig


def tiny_space() -> ParamSpace:
    return ParamSpace(
        family="dram_pim",
        axes={
            "channels_per_layer": (1, 2),
            "banks_per_channel": (2, 4),
            "columns_per_bank": (64, 128),
        },
        constraints=[
            lambda p: p["channels_per_layer"] * p["banks_per_channel"] <= 4,
        ],
        defaults={"channels_per_layer": 2, "banks_per_channel": 2,
                  "columns_per_bank": 64},
    )


def tiny_request(**kw) -> MappingRequest:
    base = dict(network="resnet18", mode="transform", explorer="grid",
                budget=4, n_candidates=3, max_steps=256, seed=0)
    base.update(kw)
    return MappingRequest(**base)


def make_service(**kw) -> MappingService:
    kw.setdefault("space_overrides", {"dram_pim": tiny_space()})
    return MappingService(**kw)


# ---------------------------------------------------------------------------
# Request/response schemas.
# ---------------------------------------------------------------------------

def test_request_roundtrip_and_cache_key():
    req = tiny_request(objective="edp", area_budget_mm2=10.0)
    again = MappingRequest.from_dict(req.to_dict())
    assert again == req
    assert again.cache_key() == req.cache_key()
    # any field change changes the identity
    assert tiny_request(budget=5).cache_key() != req.cache_key()
    assert tiny_request(objective="edp",
                        area_budget_mm2=10.0,
                        deadline_s=1.0).cache_key() != req.cache_key()


def test_request_rejects_unknown_fields_and_bad_values():
    with pytest.raises(ValueError):
        MappingRequest.from_dict({"network": "resnet18", "objectiv": "edp"})
    with pytest.raises(ValueError):
        tiny_request(deadline_s=-1.0)
    with pytest.raises(ValueError):
        tiny_request(deadline_s=1.0, distributed=2)
    with pytest.raises(AssertionError):
        tiny_request(mode="nope")


def test_response_json_roundtrips():
    svc = make_service()
    try:
        resp = svc.request(tiny_request())
    finally:
        svc.close()
    d = json.loads(resp.to_json())
    assert d["status"] == "ok"
    assert d["best"]["arch_name"] == resp.best["arch_name"]
    assert len(d["frontier_points"]) == len(resp.frontier_points)


# ---------------------------------------------------------------------------
# Journal-as-cache semantics.
# ---------------------------------------------------------------------------

def test_repeat_request_served_from_memo_then_journal(tmp_path):
    path = str(tmp_path / "service.jsonl")
    svc = make_service(journal_path=path)
    try:
        r1 = svc.request(tiny_request())
        assert r1.served_from == "search" and r1.evaluated == 4
        r2 = svc.request(tiny_request())
        assert r2.served_from == "memo"
        assert svc.stats["sweeps"] == 1      # memo answered without a sweep
        assert r2.frontier_json == r1.frontier_json
    finally:
        svc.close()
    # a fresh service on the same journal (restart): zero new searches
    svc2 = make_service(journal_path=path)
    try:
        r3 = svc2.request(tiny_request())
        assert r3.served_from == "journal"
        assert r3.evaluated == 0 and r3.from_journal == 4
        assert r3.frontier_json == r1.frontier_json   # byte-identical
    finally:
        svc2.close()


def test_bigger_budget_request_reuses_smaller_requests_points(tmp_path):
    svc = make_service(journal_path=str(tmp_path / "service.jsonl"))
    try:
        r1 = svc.request(tiny_request(budget=2))
        assert r1.evaluated == 2
        r2 = svc.request(tiny_request(budget=4))
        # grid order is deterministic: the first 2 points come from the
        # journal, only the 2 new ones are searched
        assert r2.from_journal == 2 and r2.evaluated == 2
    finally:
        svc.close()


def test_service_frontier_matches_direct_run_dse(tmp_path):
    svc = make_service(journal_path=str(tmp_path / "service.jsonl"))
    try:
        resp = svc.request(tiny_request())
    finally:
        svc.close()
    res = run_dse(tiny_request().dse_config(), space=tiny_space(),
                  journal=RunJournal())
    assert resp.frontier_json == res.frontier.canonical_json()


# ---------------------------------------------------------------------------
# Coalescing.
# ---------------------------------------------------------------------------

def test_concurrent_identical_requests_share_one_sweep():
    svc = make_service(max_workers=1)
    gate = threading.Event()
    blocker, _ = svc._queue.submit("blocker", gate.wait)
    try:
        req = tiny_request()
        j1 = svc.submit(req)       # queued behind the blocker
        j2 = svc.submit(req)       # identical + in flight => coalesced
        assert j2 is j1
        assert j1.n_attached == 2
        assert svc.stats["coalesced"] == 1
        gate.set()
        r1, r2 = j1.result(60), j2.result(60)
        assert r1 is r2
        assert svc.stats["sweeps"] == 1
        # after completion: answered by the memo, still one sweep
        r3 = svc.request(req)
        assert r3.served_from == "memo" and svc.stats["sweeps"] == 1
    finally:
        gate.set()
        blocker.result(60)
        svc.close()


def test_different_requests_do_not_coalesce():
    svc = make_service(max_workers=1)
    try:
        j1 = svc.submit(tiny_request(seed=0))
        j2 = svc.submit(tiny_request(seed=1))
        assert j1 is not j2
        j1.result(60), j2.result(60)
        assert svc.stats["sweeps"] == 2 and svc.stats["coalesced"] == 0
    finally:
        svc.close()


def test_job_queue_propagates_errors_and_tracks_inflight():
    q = JobQueue(max_workers=1)
    try:
        def boom():
            raise RuntimeError("no")
        job, coalesced = q.submit("k", boom)
        assert not coalesced
        with pytest.raises(RuntimeError, match="no"):
            job.result(10)
        assert job.status == "failed"
        # the key left the in-flight table: a resubmit runs fresh
        ok, coalesced = q.submit("k", lambda: 42)
        assert not coalesced
        assert ok is not job and ok.result(10) == 42
        assert q.inflight() == 0
        assert Job.completed("m", 7).result(0) == 7
    finally:
        q.shutdown()


# ---------------------------------------------------------------------------
# Deadlines (best-so-far answers).
# ---------------------------------------------------------------------------

def test_deadline_returns_best_so_far_and_converges(tmp_path):
    path = str(tmp_path / "service.jsonl")
    svc = make_service(journal_path=path)
    try:
        # deadline 0: the baseline is always scored, nothing more
        r = svc.request(tiny_request(deadline_s=0.0))
        assert r.deadline_hit and r.proposed == 1
        assert r.status == "ok" and r.best is not None
        assert r.best["arch_name"] == r.baseline["arch_name"]
    finally:
        svc.close()
    # warm journal: replaying the prefix is near-free, so repeated
    # deadline requests make monotone progress through the sweep (each
    # one spends its deadline on new points and lands at least one).
    # One LIVE service throughout: deadline-truncated answers must not
    # be memoized, or the service would freeze at the first cut.
    svc = make_service(journal_path=path)
    try:
        seen = 1
        for _ in range(8):
            r = svc.request(tiny_request(deadline_s=0.2))
            assert r.served_from != "memo"
            assert r.proposed >= seen
            seen = r.proposed
            if not r.deadline_hit:
                break
        assert not r.deadline_hit       # converged to the full budget
    finally:
        svc.close()
    # the full request now needs no deadline headroom at all
    svc = make_service(journal_path=path)
    try:
        full = svc.request(tiny_request())
        assert full.evaluated == 0 and full.from_journal == 4
    finally:
        svc.close()


def test_run_dse_deadline_stats_flag():
    res = run_dse(tiny_request().dse_config(), space=tiny_space(),
                  journal=RunJournal())
    assert res.stats["deadline_hit"] is False
    res = run_dse(tiny_request().dse_config(), space=tiny_space(),
                  journal=RunJournal(), deadline_s=0.0)
    assert res.stats["deadline_hit"] is True
    assert len(res.records) >= 1          # the baseline always lands


# ---------------------------------------------------------------------------
# Area budgets and mapping materialization.
# ---------------------------------------------------------------------------

def test_area_budget_constrains_winner():
    svc = make_service()
    try:
        free = svc.request(tiny_request())
        areas = sorted(p["area_mm2"] for p in free.frontier_points)
        cap = areas[0]
        capped = svc.request(tiny_request(area_budget_mm2=cap))
        assert capped.status == "ok"
        assert capped.best["area_mm2"] <= cap + 1e-12
        infeasible = svc.request(tiny_request(area_budget_mm2=cap * 0.01))
        assert infeasible.status == "infeasible"
        assert infeasible.best is None
        assert infeasible.frontier_points    # frontier still reported
    finally:
        svc.close()


def test_area_budget_winner_honors_search_objective():
    """Under an area budget the winner minimizes the *request's*
    objective (here EDP), not unconditionally latency."""
    svc = make_service()
    try:
        free = svc.request(tiny_request(objective="edp"))
        cap = max(p["area_mm2"] for p in free.frontier_points)
        capped = svc.request(tiny_request(objective="edp",
                                          area_budget_mm2=cap))
    finally:
        svc.close()
    # ground truth from a direct sweep: min objective_value in budget
    res = run_dse(tiny_request(objective="edp").dse_config(),
                  space=tiny_space(), journal=RunJournal())
    eligible = [r for r in res.records
                if r["area_mm2"] <= cap + 1e-12]
    want = min(eligible, key=lambda r: r["objective_value"])
    assert capped.best["point_key"] == want["point_key"]
    assert capped.best["objective_value"] == want["objective_value"]


def test_include_mapping_materializes_loop_nests():
    svc = make_service()
    try:
        resp = svc.request(tiny_request(include_mapping=True))
        assert resp.mapping and len(resp.mapping) == resp.best["n_layers"]
        for lay in resp.mapping:
            assert lay["nest"] and isinstance(lay["nest"], str)
            assert lay["latency_ns"] > 0
        total = sum(lay["energy_pj"] for lay in resp.mapping)
        assert total == pytest.approx(resp.best["energy_pj"])
    finally:
        svc.close()


def test_mapping_materialization_cached_per_winner(monkeypatch):
    """The winner's loop nests are searched once and cached by the
    winning record's content key — a second request with a different
    cache key but the same winner replays them without a new search."""
    calls = []
    orig = MappingService._materialize_mapping

    def counting(self, req, best):
        calls.append(best["key"])
        return orig(self, req, best)

    monkeypatch.setattr(MappingService, "_materialize_mapping", counting)
    svc = make_service()
    try:
        r1 = svc.request(tiny_request(include_mapping=True,
                                      deadline_s=300.0))
        assert not r1.deadline_hit and r1.mapping
        # different deadline => different cache key => memo miss, but
        # the journal-served sweep picks the same winner
        r2 = svc.request(tiny_request(include_mapping=True,
                                      deadline_s=301.0))
        assert r2.served_from == "journal"
        assert r2.mapping == r1.mapping
        assert len(calls) == 1
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Serve LM engine: the fast (non-compiling) sampling paths.
# ---------------------------------------------------------------------------

def _bare_engine(**scfg) -> Engine:
    # _sample needs only the config — skip __init__'s jit/model setup
    eng = object.__new__(Engine)
    eng.scfg = ServeConfig(**scfg)
    return eng


def test_engine_sample_greedy_is_argmax():
    eng = _bare_engine(temperature=0.0)
    logits = np.array([[0.1, 2.0, -1.0], [3.0, 0.0, 1.0]], np.float32)
    out = np.asarray(eng._sample(logits, None))
    np.testing.assert_array_equal(out, [1, 0])
    assert out.dtype == np.int32


def test_engine_sample_temperature_seeded_and_in_vocab():
    import jax
    eng = _bare_engine(temperature=0.7)
    logits = np.array([[0.5, 1.5, 0.0, -2.0]] * 8, np.float32)
    a = np.asarray(eng._sample(logits, jax.random.PRNGKey(0)))
    b = np.asarray(eng._sample(logits, jax.random.PRNGKey(0)))
    np.testing.assert_array_equal(a, b)      # deterministic in the key
    assert ((a >= 0) & (a < 4)).all()
    # low temperature concentrates on the argmax
    cold = np.asarray(_bare_engine(temperature=1e-4)._sample(
        logits, jax.random.PRNGKey(1)))
    np.testing.assert_array_equal(cold, np.ones_like(cold))
