"""Loop-aware HLO cost analyzer: exactness vs XLA on loop-free modules,
trip-count multiplication on (nested) scans, collective parsing."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import hlo_cost
from repro.roofline.analysis import Roofline, model_flops


def _flops(fn, *shapes):
    comp = jax.jit(fn).lower(*shapes).compile()
    return hlo_cost.analyze(comp.as_text()), comp


def _xla_cost(comp):
    ca = comp.cost_analysis()  # newer jax returns a one-element list
    return ca[0] if isinstance(ca, list) else ca


def test_loopfree_matches_xla():
    def f(a, b, c):
        return (a @ b) @ c
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    mine, comp = _flops(f, a, b, c)
    expect = 2 * 128 * 256 * 512 + 2 * 128 * 512 * 64
    assert mine.flops == expect
    assert float(_xla_cost(comp).get("flops")) == expect


def test_scan_trip_count_multiplied():
    def g(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    mine, comp = _flops(g, x, w)
    assert mine.flops == 10 * 2 * 64 ** 3
    # XLA counts the body once — exactly the failure mode we fix
    assert float(_xla_cost(comp).get("flops")) < mine.flops


def test_nested_scan():
    def h(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    mine, _ = _flops(h, x, w)
    assert mine.flops == 15 * 2 * 64 ** 3


def test_dot_bytes_counted():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    mine, _ = _flops(f, a, b)
    expect = 4 * (128 * 256 + 256 * 64 + 128 * 64)
    assert mine.bytes >= expect


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, hbm_bytes=819e9 * 2,
                 collective_bytes=50e9 * 0.5, chips=256, per_device=True)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.total_s == pytest.approx(2.0)


def test_model_flops():
    assert model_flops(1_000_000, 100, training=True) == 6e8
    assert model_flops(1_000_000, 100, active_params=250_000,
                       training=False) == 5e7


def test_collective_parse_shapes():
    txt = """
ENTRY %main (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
  ROOT %ar = f32[4,4]{1,0} all-reduce(%p), to_apply=%add
}
"""
    c = hlo_cost.analyze(txt)
    assert c.coll_bytes == 64
    assert c.coll_counts["all-reduce"] == 1
