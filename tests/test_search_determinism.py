"""Search-strategy determinism: same ``SearchConfig.seed`` => identical
``NetworkResult``, for every strategy — and every objective — on both
the engine and reference paths.

Candidate generation is the only stochastic element of the search
(``candidates`` seeds a fresh ``random.Random`` per layer from
``cfg.seed``), so repeated runs — including runs on fresh engines, or
interleaved with searches under other seeds/archs — must reproduce the
chosen mappings and every schedule number bit-for-bit. The DSE journal's
resume contract (``repro.dse.persist``) assumes exactly this. The
energy-aware objectives (DESIGN.md Section 9) extend the engine's
equivalence contract: for every (strategy, mode, objective) the engine
must match the reference path on every latency AND energy number.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (LayerSpec, SearchConfig, chain_edges, dram_pim,
                        optimize_network)
from repro.core.engine import OverlapEngine, optimize_network_engine
from repro.core.search import (MODES, OBJECTIVES, STRATEGIES,
                               _optimize_network_reference)

ENERGY_OBJECTIVES = tuple(o for o in OBJECTIVES if o != "latency")


def small_arch():
    return dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=64)


def conv_chain():
    return [
        LayerSpec("l0", K=8, C=4, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l1", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l2", K=16, C=8, P=4, Q=4, R=3, S=3, stride=2, pad=1),
        LayerSpec("l3", K=16, C=16, P=4, Q=4, R=3, S=3, pad=1),
    ]


def cfg(**kw):
    base = dict(n_candidates=8, seed=11, max_steps=512, mode="transform")
    base.update(kw)
    return SearchConfig(**base)


def assert_results_identical(a, b):
    assert a.total_ns == b.total_ns
    assert a.per_layer_ns == b.per_layer_ns
    assert a.objective == b.objective
    assert a.total_energy_pj == b.total_energy_pj
    assert a.summary() == b.summary()
    for la, lb in zip(a.layers, b.layers):
        assert la.mapping.blocks == lb.mapping.blocks
        assert la.start_ns == lb.start_ns and la.end_ns == lb.end_ns
        assert np.array_equal(la.finish_ns, lb.finish_ns)
        assert la.transformed == lb.transformed
        assert la.moved_frac == lb.moved_frac
        assert la.moved_bytes == lb.moved_bytes
        assert la.move_energy_pj == lb.move_energy_pj


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_engine_path_deterministic(strategy):
    """Two engine runs (fresh engines) with one seed are bit-identical."""
    net, arch = conv_chain(), small_arch()
    edges = chain_edges(net)
    c = cfg(strategy=strategy)
    a = optimize_network_engine(net, edges, arch, c,
                                engine=OverlapEngine())
    b = optimize_network_engine(net, edges, arch, c,
                                engine=OverlapEngine())
    assert_results_identical(a, b)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_reference_path_deterministic(strategy):
    net, arch = conv_chain(), small_arch()
    edges = chain_edges(net)
    c = cfg(strategy=strategy)
    a = _optimize_network_reference(net, edges, arch, c)
    b = _optimize_network_reference(net, edges, arch, c)
    assert_results_identical(a, b)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_engine_matches_reference_per_strategy(strategy):
    """Determinism must hold *across* the two paths too (the engine's
    equivalence contract restated at NetworkResult granularity)."""
    net, arch = conv_chain(), small_arch()
    edges = chain_edges(net)
    c = cfg(strategy=strategy)
    a = optimize_network(net, edges, arch, c)
    b = optimize_network(net, edges, arch,
                         dataclasses.replace(c, use_engine=False))
    assert_results_identical(a, b)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_deterministic_under_interleaving(strategy):
    """A shared engine serving other seeds and other archs in between
    must not perturb a re-run (cache reuse is bit-exact, and candidate
    RNG state is per-call)."""
    net, arch = conv_chain(), small_arch()
    edges = chain_edges(net)
    c = cfg(strategy=strategy)
    eng = OverlapEngine()
    a = optimize_network_engine(net, edges, arch, c, engine=eng)
    # interleave: different seed, then a different architecture
    optimize_network_engine(net, edges, arch, cfg(seed=99, strategy=strategy),
                            engine=eng)
    other = dataclasses.replace(arch, word_bits=8)
    optimize_network_engine(net, edges, other, c, engine=eng)
    b = optimize_network_engine(net, edges, arch, c, engine=eng)
    assert_results_identical(a, b)


@pytest.mark.parametrize("objective", ENERGY_OBJECTIVES)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_engine_matches_reference_per_objective(strategy, mode, objective):
    """The engine's equivalence contract extended to the energy-aware
    objectives: all four strategies x all three modes x each new
    objective must produce identical NetworkResults (latency AND energy
    numbers) under the engine and reference paths at the same seed."""
    net, arch = conv_chain(), small_arch()
    edges = chain_edges(net)
    c = cfg(strategy=strategy, mode=mode, objective=objective)
    a = optimize_network(net, edges, arch, c)
    b = optimize_network(net, edges, arch,
                         dataclasses.replace(c, use_engine=False))
    assert_results_identical(a, b)


@pytest.mark.parametrize("objective", OBJECTIVES)
def test_engine_matches_reference_objective_refine(objective):
    """The refine loop compares whole-network objective values; engine
    (incremental re-evaluation) and reference must still agree for every
    objective."""
    net, arch = conv_chain(), small_arch()
    edges = chain_edges(net)
    c = cfg(mode="transform", objective=objective, refine_passes=1,
            refine_candidates=4)
    a = optimize_network(net, edges, arch, c)
    b = optimize_network(net, edges, arch,
                         dataclasses.replace(c, use_engine=False))
    assert_results_identical(a, b)


@pytest.mark.parametrize("objective", ENERGY_OBJECTIVES)
def test_objective_deterministic_under_interleaving(objective):
    """A shared engine serving other objectives in between must not
    perturb a re-run: score caches are objective-keyed."""
    net, arch = conv_chain(), small_arch()
    edges = chain_edges(net)
    c = cfg(objective=objective)
    eng = OverlapEngine()
    a = optimize_network_engine(net, edges, arch, c, engine=eng)
    for other in OBJECTIVES:
        if other != objective:
            optimize_network_engine(net, edges, arch, cfg(objective=other),
                                    engine=eng)
    b = optimize_network_engine(net, edges, arch, c, engine=eng)
    assert_results_identical(a, b)


def test_objective_stamped_on_result():
    net, arch = conv_chain(), small_arch()
    edges = chain_edges(net)
    for objective in OBJECTIVES:
        r = optimize_network(net, edges, arch, cfg(objective=objective))
        assert r.objective == objective
        assert r.summary()["objective"] == objective


def test_seed_actually_matters():
    """Different seeds explore different candidate pools (sanity check
    that the determinism tests are not vacuous)."""
    net, arch = conv_chain(), small_arch()
    edges = chain_edges(net)
    a = optimize_network(net, edges, arch, cfg(seed=11))
    b = optimize_network(net, edges, arch, cfg(seed=12))
    blocks_a = [l.mapping.blocks for l in a.layers]
    blocks_b = [l.mapping.blocks for l in b.layers]
    assert blocks_a != blocks_b
