"""LLM workload lowering: differential engine-vs-reference pinning,
golden MAC accounting, and structural property tests.

The lowering layer (``repro.workloads``) introduces new coordinate maps
(``FullMap``, grouped ``WeightMap``) and new network topologies (MoE
fan-out, SSD batched matmuls, cross-attention). Three things must hold:

* **Differential**: the batched engine and the reference path
  (``use_engine=False``) produce bit-identical ``NetworkResult``s on
  every zoo smoke config x {prefill, decode} — the engine equivalence
  contract extended over the whole lowered zoo, and over every (mode,
  objective) pair on one MoE and one SSM representative.
* **Golden MACs**: ``sum(l.macs)`` of a lowered block equals the
  analytic per-block FLOP count derived independently from the
  ``ModelConfig`` (exclusions per DESIGN.md Section 15: norms, softmax,
  RoPE, activations, router gate, depthwise convs, residuals,
  embeddings).
* **Invariants**: edges only point backward at valid producers, decode
  shapes never depend on any prefill length, matmul-only chains never
  trigger pool inference, and the new maps agree with OverlaPIM's
  exhaustive overlap analysis (the C2 oracle).
"""
import dataclasses
import math
import random

import numpy as np
import pytest

try:  # property tests prefer hypothesis; fall back to fixed seeded draws
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _prop_fallback import given, settings, st

from repro.configs import ARCH_IDS, get_config
from repro.core import (FullMap, IdentityMap, SearchConfig, WeightMap,
                        describe, dram_pim, matmul, optimize_network,
                        random_mapping, ready_steps_analytical,
                        ready_steps_exhaustive)
from repro.core.search import MODES, OBJECTIVES
from repro.workloads import lower, moe_capacity, parse_scenario

SMOKE_ARCHS = [a + "_smoke" for a in ARCH_IDS]


def small_arch():
    return dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=64)


def cfg(**kw):
    base = dict(n_candidates=3, seed=7, max_steps=128, mode="transform")
    base.update(kw)
    return SearchConfig(**base)


def assert_results_identical(a, b):
    assert a.total_ns == b.total_ns
    assert a.per_layer_ns == b.per_layer_ns
    assert a.objective == b.objective
    assert a.total_energy_pj == b.total_energy_pj
    assert a.summary() == b.summary()
    for la, lb in zip(a.layers, b.layers):
        assert la.mapping.blocks == lb.mapping.blocks
        assert la.start_ns == lb.start_ns and la.end_ns == lb.end_ns
        assert np.array_equal(la.finish_ns, lb.finish_ns)
        assert la.transformed == lb.transformed
        assert la.moved_frac == lb.moved_frac
        assert la.moved_bytes == lb.moved_bytes
        assert la.move_energy_pj == lb.move_energy_pj


# ---------------------------------------------------------------------------
# Differential: engine == reference over the whole lowered zoo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("phase", ["prefill", "decode"])
@pytest.mark.parametrize("arch_id", SMOKE_ARCHS)
def test_engine_matches_reference_all_smoke(arch_id, phase):
    """Every zoo smoke config, both phases: engine and reference runs
    with one seed must produce bit-identical NetworkResults."""
    desc = describe(f"{arch_id}:{phase}")
    c = cfg()
    a = optimize_network(desc.layers, desc.edges, small_arch(), c)
    b = optimize_network(desc.layers, desc.edges, small_arch(),
                         dataclasses.replace(c, use_engine=False))
    assert_results_identical(a, b)


@pytest.mark.parametrize("objective", OBJECTIVES)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize(
    "scenario", ["deepseek_moe_16b_smoke:decode@16",
                 "mamba2_780m_smoke:prefill@32"])
def test_engine_matches_reference_modes_objectives(scenario, mode,
                                                   objective):
    """MoE fan-out and SSD topologies under every (mode, objective):
    the equivalence contract must survive FullMap edges and batched
    matmuls on every search configuration, not just the default."""
    desc = describe(scenario)
    c = cfg(mode=mode, objective=objective)
    a = optimize_network(desc.layers, desc.edges, small_arch(), c)
    b = optimize_network(desc.layers, desc.edges, small_arch(),
                         dataclasses.replace(c, use_engine=False))
    assert_results_identical(a, b)


# ---------------------------------------------------------------------------
# Golden MAC accounting (analytic formulas, derived independently)
# ---------------------------------------------------------------------------

_FAC = {"swiglu": 3, "gelu": 2}


def _ffn_macs(c, tokens):
    return _FAC[c.mlp] * tokens * c.d_model * c.d_ff


def _attn_macs(c, q, kv, kv_proj_tokens):
    """q/k/v/out projections + the two head-batched score matmuls.
    ``kv_proj_tokens`` is how many tokens the K/V projections process
    (1 in decode — the cache predates the step; enc_frames in cross)."""
    h, kvh, hd = c.n_heads, max(c.n_kv_heads, 1), c.hd
    return (q * c.d_model * h * hd
            + 2 * kv_proj_tokens * c.d_model * kvh * hd
            + 2 * h * q * kv * hd
            + q * h * hd * c.d_model)


def _moe_macs(c, q, kv):
    cap = max(1, math.ceil(q / max(c.moe_shards, 1) * c.top_k
                           / c.n_experts * c.capacity_factor))
    return (_attn_macs(c, q, kv, q if q == kv else 1)
            + q * c.d_model * c.n_experts
            + c.n_shared_experts * _ffn_macs(c, q)
            + c.n_experts * _FAC[c.mlp] * cap * c.d_model * c.d_ff)


def _ssd_macs(c, phase, tokens):
    d, di = c.d_model, c.d_inner
    h, p, g, n = c.ssm_heads, c.ssm_head_dim, c.ssm_groups, c.ssm_state
    proj = tokens * d * (2 * di + 2 * g * n + h)
    if phase == "prefill":
        ck = min(c.ssm_chunk, tokens)
        nc = math.ceil(tokens / ck)
        dual = nc * h * (ck * n * ck + ck * ck * p
                         + n * ck * p + ck * n * p)
        return proj + dual + tokens * di * d
    return proj + 2 * h * n * p + di * d


def _audio_macs(c, phase, length, blocks):
    f = c.enc_frames
    h, hd = c.n_heads, c.hd
    if phase == "prefill":
        stem = c.d_model * 80 * (2 * f) * 3 + c.d_model ** 2 * f * 3
        enc = _attn_macs(c, f, f, f) + _ffn_macs(c, f)
        s = length
        cross = _attn_macs(c, s, f, f)
        dec = _attn_macs(c, s, s, s) + cross + _ffn_macs(c, s)
        return stem + enc + blocks * dec
    # decode: cached cross K/V -> only q/qk/av/out on the cross path
    cross = (c.d_model * h * hd + 2 * h * f * hd
             + h * hd * c.d_model)
    dec = _attn_macs(c, 1, length, 1) + cross + _ffn_macs(c, 1)
    return blocks * dec


def analytic_macs(c, phase, length, blocks=1):
    """Independent per-model MAC count of ``lower(c, phase, ...)``."""
    fam = c.family
    if fam == "audio":
        return _audio_macs(c, phase, length, blocks)
    extra = 0
    if fam == "vlm" and phase == "prefill":
        gh = math.isqrt(c.img_tokens)
        gh, gw = (gh, gh) if gh * gh == c.img_tokens \
            else (c.img_tokens, 1)
        extra = (c.d_model * 3 * gh * gw * 14 * 14
                 + c.img_tokens * c.d_model ** 2)
        length = length + c.img_tokens
    q, kv = (length, length) if phase == "prefill" else (1, length)
    if fam == "moe":
        block = _moe_macs(c, q, kv)
    elif fam == "ssm":
        block = _ssd_macs(c, phase, q)
    elif fam == "hybrid":
        block = (_ssd_macs(c, phase, q)
                 + _attn_macs(c, q, kv, q if phase == "prefill" else 1)
                 + _ffn_macs(c, q))
    else:  # dense, vlm
        block = (_attn_macs(c, q, kv, q if phase == "prefill" else 1)
                 + _ffn_macs(c, q))
    return extra + blocks * block


@pytest.mark.parametrize("smoke", [True, False], ids=["smoke", "full"])
@pytest.mark.parametrize("phase", ["prefill", "decode"])
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_golden_mac_accounting(arch_id, phase, smoke):
    """sum(l.macs) over a lowered block == the analytic count."""
    c = get_config(arch_id, smoke=smoke)
    length = (64 if smoke else 512) if phase == "prefill" \
        else (16 if smoke else 256)
    layers, _ = lower(c, phase, seq=length, kv_len=length)
    assert sum(l.macs for l in layers) == analytic_macs(c, phase, length)


@pytest.mark.parametrize("arch_id", ["deepseek_moe_16b", "zamba2_1_2b",
                                     "whisper_base", "llava_next_34b"])
def test_golden_macs_multi_block(arch_id):
    """blocks=N scales the repeating tranche only — frontends (vision
    patch-embed, whisper stem+encoder) are lowered once."""
    c = get_config(arch_id, smoke=True)
    layers, _ = lower(c, "prefill", seq=32, blocks=3)
    assert sum(l.macs for l in layers) == analytic_macs(c, "prefill", 32,
                                                        blocks=3)


def test_moe_capacity_formula():
    c = get_config("deepseek_moe_16b")
    cap = moe_capacity(c, 2048)
    assert cap == math.ceil(2048 / c.moe_shards * c.top_k
                            / c.n_experts * c.capacity_factor)
    assert moe_capacity(c, 1) == 1  # floor: never zero slots


# ---------------------------------------------------------------------------
# Lowering invariants (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(arch_idx=st.integers(0, len(ARCH_IDS) - 1),
       phase=st.sampled_from(["prefill", "decode"]),
       length=st.integers(1, 96),
       blocks=st.integers(1, 3))
def test_property_edges_backward(arch_idx, phase, length, blocks):
    """Every edge points at an already-built layer (DAG by
    construction), for any shape in the supported range."""
    c = get_config(ARCH_IDS[arch_idx], smoke=True)
    layers, edges = lower(c, phase, seq=length, kv_len=length,
                          blocks=blocks)
    assert len(layers) == len(edges)
    for i, deps in enumerate(edges):
        for e in deps:
            assert 0 <= e.producer < i


@settings(max_examples=10, deadline=None)
@given(arch_idx=st.integers(0, len(ARCH_IDS) - 1),
       kv_len=st.integers(1, 64))
def test_property_decode_independent_of_seq(arch_idx, kv_len):
    """Decode lowers one step against the KV length; the prefill
    ``seq`` argument must be entirely inert."""
    c = get_config(ARCH_IDS[arch_idx], smoke=True)
    a_layers, a_edges = lower(c, "decode", seq=7, kv_len=kv_len)
    b_layers, b_edges = lower(c, "decode", seq=4096, kv_len=kv_len)
    assert a_layers == b_layers
    assert [[(e.producer, e.cmap.key()) for e in deps]
            for deps in a_edges] == \
        [[(e.producer, e.cmap.key()) for e in deps] for deps in b_edges]


@pytest.mark.parametrize("arch_id", SMOKE_ARCHS)
def test_no_pool_inference_on_matmul_chains(arch_id):
    """The lowering constructs every IdentityMap explicitly with
    pool=1; matmul-only chains must never pick up an inferred pooling
    factor (that is a conv-chain heuristic)."""
    for phase in ("prefill", "decode"):
        desc = describe(f"{arch_id}:{phase}")
        for deps in desc.edges:
            for e in deps:
                if isinstance(e.cmap, IdentityMap):
                    assert e.cmap.pool == 1


@pytest.mark.parametrize("cmap_kind", ["full", "grouped_weight"])
@pytest.mark.parametrize("seed", range(4))
def test_new_maps_analytical_equals_exhaustive(cmap_kind, seed):
    """C2 oracle for the maps this layer introduced: the analytical
    ready-step analysis must agree with OverlaPIM's exhaustive
    traversal under FullMap and grouped WeightMap edges."""
    rng = random.Random(seed)
    q_len, hd, group = 4, 4, 2
    h = 4  # heads; kv heads = h // group
    # shapes as the lowering builds them: k_proj emits q_len rows of
    # (h//group)*hd columns; qk consumes them as its stationary operand
    lp = matmul("kproj", q_len, 8, (h // group) * hd)
    lc = matmul("qk", q_len, hd, q_len, batch=h)
    arch = dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=8)
    mp = random_mapping(lp, arch, rng, max_steps=256)
    mc = random_mapping(lc, arch, rng, max_steps=256)
    cmap = FullMap() if cmap_kind == "full" else \
        WeightMap(q_len, hd, "qk_weight", group)
    sa, ra = ready_steps_analytical(mp, mc, cmap)
    se, re = ready_steps_exhaustive(mp, mc, cmap)
    assert np.array_equal(ra, re)
    assert np.array_equal(sa[~ra], se[~ra])


def test_weightmap_group_in_key():
    """Grouped maps must not collide with ungrouped ones in engine
    caches (the key IS the cache identity)."""
    assert WeightMap(8, 4, "qk_weight", 1).key() != \
        WeightMap(8, 4, "qk_weight", 4).key()
    assert FullMap().key() == ("full",)


# ---------------------------------------------------------------------------
# Scenario grammar + describe kwargs contract
# ---------------------------------------------------------------------------

def test_scenario_roundtrip_and_defaults():
    sc = parse_scenario("deepseek_moe_16b:prefill@2048")
    assert sc.name == "deepseek_moe_16b:prefill@2048"
    assert parse_scenario("mamba2_780m").phase == "prefill"
    assert parse_scenario("mamba2_780m_smoke:decode").length == 16
    assert parse_scenario("granite-8b-smoke:prefill@64x2").blocks == 2


def test_scenario_errors():
    with pytest.raises(KeyError):
        parse_scenario("not_a_model:prefill")
    with pytest.raises(ValueError):
        parse_scenario("olmo_1b:training")
    with pytest.raises(ValueError):
        parse_scenario("olmo_1b:prefill@0")


def test_describe_rejects_kwargs_on_fixed_networks():
    """describe('resnet18', seq=99) used to silently ignore the kwarg
    and hand back the stock network — now it must raise."""
    with pytest.raises(TypeError):
        describe("resnet18", seq=99)
    with pytest.raises(TypeError):
        describe("vgg16", heads=4)


def test_describe_scenario_kwargs():
    d = describe("olmo_1b_smoke:prefill", seq=32)
    assert "@32" in d.name
    assert any(l.P == 32 for l in d.layers)
    with pytest.raises(TypeError):
        describe("olmo_1b_smoke:prefill", bogus=1)
    # bert keeps its existing kwargs contract
    d = describe("bert_encoder", seq=64, heads=4, d_model=64, d_ff=128)
    assert len(d.layers) == 8


def test_describe_unknown_network():
    with pytest.raises(KeyError):
        describe("definitely_not_a_network")
