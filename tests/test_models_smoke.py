"""Per-architecture smoke tests: reduced same-family configs, one forward
+ one decode step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # per-arch forward/decode XLA compiles

from repro.configs import ARCH_IDS, get_config
from repro.models import model_zoo
from repro.models.inputs import make_decode_tokens, make_train_batch

B, S = 2, 32


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for a in ARCH_IDS:
        cfg = get_config(a, smoke=True)
        params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
        out[a] = (cfg, params)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(zoo, arch):
    cfg, params = zoo[arch]
    batch = make_train_batch(cfg, B, S)
    logits, aux = model_zoo.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_and_grads_finite(zoo, arch):
    cfg, params = zoo[arch]
    batch = make_train_batch(cfg, B, S)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model_zoo.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    # loss near log(vocab) at random init (logits ~ small)
    assert 0.5 * np.log(cfg.vocab) < float(metrics["ce"]) \
        < 2.5 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(zoo, arch):
    cfg, params = zoo[arch]
    cache = model_zoo.init_cache(cfg, B, S)
    if cfg.family == "audio":
        from repro.models import encdec
        frames = jnp.zeros((B, cfg.enc_frames, cfg.d_model),
                           cfg.compute_dtype)
        cache = encdec.prime_cross_cache(cfg, params, cache, frames)
    toks = make_decode_tokens(cfg, B)
    logits, cache2 = model_zoo.decode_step(cfg, params, cache, toks)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache2["pos"]) == 1
    logits3, _ = model_zoo.decode_step(cfg, params, cache2, toks)
    assert bool(jnp.isfinite(logits3.astype(jnp.float32)).all())


def test_vlm_image_embeds_path(zoo):
    cfg, params = zoo["llava_next_34b"]
    batch = make_train_batch(cfg, B, S)
    img = jnp.zeros((B, cfg.img_tokens, cfg.d_model), cfg.compute_dtype)
    logits, _ = model_zoo.forward(
        cfg, params, {**batch, "extra_embeds": img})
    assert logits.shape == (B, S, cfg.padded_vocab)


def test_moe_gather_equals_einsum():
    """Both dispatch implementations route identically -> same outputs."""
    import dataclasses
    from repro.models.mlp import init_moe, moe_einsum, moe_gather
    cfg = get_config("deepseek_moe_16b", smoke=True).with_(moe_shards=2)
    params = init_moe(cfg, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32)
    yg, ag = moe_gather(cfg, params, x)
    ye, ae = moe_einsum(cfg, params, x)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(ye),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(ag), float(ae), rtol=1e-5)


def test_moe_capacity_drops_consistently():
    from repro.models.mlp import init_moe, moe_einsum, moe_gather
    cfg = get_config("granite_moe_1b_a400m", smoke=True).with_(
        capacity_factor=0.5)
    params = init_moe(cfg, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model))
    yg, _ = moe_gather(cfg, params, x)
    ye, _ = moe_einsum(cfg, params, x)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(ye),
                               rtol=2e-5, atol=2e-5)
