"""Differential tests for the batched candidate scorer (DESIGN.md §6).

The reference per-candidate path (``search._score_forward``,
``use_engine=False``) is the oracle: every batched score must be
*bit-identical* to it — the batch restructuring only reorders exact
integer/float operations that are reassociation-safe (see DESIGN.md §6
for the argument per stage).
"""
import random

import numpy as np
import pytest

from repro.core import SearchConfig, chain_edges, describe, dram_pim, \
    optimize_network
from repro.core.dataspace import (rect_bounds, rect_bounds_separable,
                                  rect_bounds_separable_stacked,
                                  rect_bounds_stacked)
from repro.core.engine import OverlapEngine
from repro.core.overlap import stream_tail_fraction, stream_tail_fractions
from repro.core.search import LayerSpec, _consumers_of, _score_forward, \
    candidates
from repro.core.transform import transform_end_grouped, transform_schedule


def _arch():
    return dram_pim(2, 2, 4)


def _pools(desc, arch, cfg):
    return [candidates(desc.layers[i], arch, cfg, salt=i)
            for i in range(len(desc.layers))]


# ---------------------------------------------------------------------------
# transform_end_grouped vs transform_schedule on dense random matrices
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_transform_end_grouped_matches_schedule(seed):
    rng = random.Random(seed)
    nb = rng.choice([1, 2, 4])
    nt = rng.choice([3, 8, 16])
    step_ns = rng.choice([1.0, 2.5])
    tile_move = rng.choice([0.0, 3.0])
    # few distinct values -> lots of ties, the regime grouping exploits
    vals_pool = sorted(rng.sample(range(0, 50), rng.choice([2, 3, 5])))
    ready = np.array([[float(rng.choice(vals_pool)) for _ in range(nt)]
                      for _ in range(nb)])
    tr = transform_schedule(ready, step_ns, tile_move)

    uniq = np.unique(ready)
    counts = np.zeros((1, uniq.size, nb), dtype=np.int64)
    for b in range(nb):
        for t in range(nt):
            counts[0, np.searchsorted(uniq, ready[b, t]), b] += 1
    end, moved = transform_end_grouped(
        uniq[None, :], counts, np.array([nt]), np.array([step_ns]),
        np.array([tile_move]))
    assert float(end[0]) == tr.end_ns
    assert int(moved[0]) == int(round(tr.moved_frac * nb * nt))


def test_transform_end_grouped_padded_batch():
    """Rows padded with zero-count value slots must not change the end."""
    ready = np.array([[0.0, 4.0, 4.0], [2.0, 2.0, 6.0]])
    tr = transform_schedule(ready, 1.5, 2.0)
    uniq = np.unique(ready)
    counts = np.zeros((1, uniq.size + 3, 2), dtype=np.int64)
    values = np.zeros((1, uniq.size + 3))
    values[0, :uniq.size] = uniq
    for b in range(2):
        for t in range(3):
            counts[0, np.searchsorted(uniq, ready[b, t]), b] += 1
    end, moved = transform_end_grouped(
        values, counts, np.array([3]), np.array([1.5]), np.array([2.0]))
    assert float(end[0]) == tr.end_ns


# ---------------------------------------------------------------------------
# stacked rect bounds vs per-candidate
# ---------------------------------------------------------------------------

def _some_mappings():
    desc = describe("resnet18")
    cfg = SearchConfig(n_candidates=5, seed=2, max_steps=1024)
    return candidates(desc.layers[1], _arch(), cfg, salt=1)


def test_rect_bounds_stacked_matches_per_candidate():
    ms = _some_mappings()
    lo_s, hi_s, offs = rect_bounds_stacked(ms)
    for j, m in enumerate(ms):
        lo, hi = rect_bounds(m)
        a, b = int(offs[j]), int(offs[j + 1])
        for d in lo:
            assert np.array_equal(lo_s[d][a:b], lo[d].reshape(-1))
            assert np.array_equal(hi_s[d][a:b], hi[d].reshape(-1))


def test_rect_bounds_separable_stacked_matches_per_candidate():
    ms = _some_mappings()
    bank_s, step_s, exts, boff, toff = rect_bounds_separable_stacked(ms)
    for j, m in enumerate(ms):
        bank, step, ext = rect_bounds_separable(m)
        b0, b1 = int(boff[j]), int(boff[j + 1])
        t0, t1 = int(toff[j]), int(toff[j + 1])
        assert exts[j] == ext
        for d in bank:
            assert np.array_equal(bank_s[d][b0:b1], bank[d])
            assert np.array_equal(step_s[d][t0:t1], step[d])


# ---------------------------------------------------------------------------
# stream_tail_fractions vs the scalar function
# ---------------------------------------------------------------------------

def test_stream_tail_fractions_matches_scalar():
    desc = describe("resnet18")
    cfg = SearchConfig(n_candidates=6, seed=0, max_steps=2048)
    for i in (0, 7, 18):
        ms = candidates(desc.layers[i], _arch(), cfg, salt=i)
        got = stream_tail_fractions(ms)
        want = [stream_tail_fraction(m) for m in ms]
        assert list(got) == want


# ---------------------------------------------------------------------------
# score_forward_batch vs the reference _score_forward, layer by layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,objective", [("overlap", "latency"),
                                            ("transform", "latency"),
                                            ("transform", "edp")])
def test_score_batch_matches_reference_resnet18(mode, objective):
    """Every batched score equals the reference score bit-for-bit, on all
    resnet18 layers (including the multi-edge residual joins) against a
    committed chain."""
    desc = describe("resnet18")
    arch = _arch()
    cfg = SearchConfig(n_candidates=6, seed=1, max_steps=1024, mode=mode,
                       objective=objective)
    res = optimize_network(desc.layers, desc.edges, arch, cfg)
    done = {i: lr for i, lr in enumerate(res.layers)}
    pools = _pools(desc, arch, cfg)
    eng = OverlapEngine()
    multi = 0
    for i, pool in enumerate(pools):
        if not desc.edges[i]:
            continue
        multi += len(desc.edges[i]) > 1
        has_cons = bool(_consumers_of(desc.edges, i))
        got = eng.score_forward_batch(i, pool, desc.edges, done, mode,
                                      has_cons, objective)
        want = [_score_forward(i, m, desc.edges, done, mode, has_cons,
                               objective) for m in pool]
        assert list(got) == want, f"layer {i} diverged"
    assert multi > 0          # the residual joins actually exercised
    assert eng._cur.sepcls    # ... through the class-histogram fast path


def test_score_batch_matches_reference_bert(mode="transform"):
    """bert_encoder's attention edges exercise the non-identity coordinate
    maps (the generic batched ready-step path + per-candidate fallback)."""
    desc = describe("bert_encoder", seq=16, d_model=8, heads=2, d_ff=16)
    arch = _arch()
    cfg = SearchConfig(n_candidates=6, seed=3, max_steps=512, mode=mode)
    res = optimize_network(desc.layers, desc.edges, arch, cfg)
    done = {i: lr for i, lr in enumerate(res.layers)}
    pools = _pools(desc, arch, cfg)
    eng = OverlapEngine()
    for i, pool in enumerate(pools):
        if not desc.edges[i]:
            continue
        has_cons = bool(_consumers_of(desc.edges, i))
        got = eng.score_forward_batch(i, pool, desc.edges, done, mode,
                                      has_cons)
        want = [_score_forward(i, m, desc.edges, done, mode, has_cons)
                for m in pool]
        assert list(got) == want, f"layer {i} diverged"


def test_score_batch_memo_returns_identical_scores():
    """Re-scoring the same pool against the same committed producers hits
    the pool memo and must return the exact same vector."""
    desc = describe("resnet18")
    arch = _arch()
    cfg = SearchConfig(n_candidates=4, seed=5, max_steps=512)
    res = optimize_network(desc.layers, desc.edges, arch, cfg)
    done = {i: lr for i, lr in enumerate(res.layers)}
    pool = candidates(desc.layers[1], arch, cfg, salt=1)
    eng = OverlapEngine()
    a = eng.score_forward_batch(1, pool, desc.edges, done, "transform")
    b = eng.score_forward_batch(1, pool, desc.edges, done, "transform")
    assert np.array_equal(a, b)
    assert a is not b         # callers own the returned vector


# ---------------------------------------------------------------------------
# end-to-end equality, engine vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["overlap", "transform"])
def test_e2e_engine_matches_reference(mode):
    net = [LayerSpec("a", K=8, C=3, P=16, Q=16, R=3, S=3),
           LayerSpec("b", K=8, C=8, P=16, Q=16, R=3, S=3),
           LayerSpec("c", K=4, C=8, P=8, Q=8, R=3, S=3, stride=2)]
    edges = chain_edges(net)
    arch = _arch()
    cfg = SearchConfig(n_candidates=8, seed=4, max_steps=1024, mode=mode,
                       refine_passes=1)
    a = optimize_network(net, edges, arch, cfg)
    b = optimize_network(net, edges, arch,
                         SearchConfig(n_candidates=8, seed=4,
                                      max_steps=1024, mode=mode,
                                      refine_passes=1, use_engine=False))
    assert a.total_ns == b.total_ns
    assert [la.mapping.cache_key for la in a.layers] == \
        [lb.mapping.cache_key for lb in b.layers]
