"""Data space generation: analytical == exhaustive (paper C1), coverage,
disjointness, point location."""
import random

import numpy as np
import pytest

try:  # property tests prefer hypothesis; fall back to fixed seeded draws
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _prop_fallback import given, settings, st

from repro.core import (LayerSpec, dram_pim, generate_analytical,
                        generate_exhaustive, heuristic_mapping,
                        locate_finish, locate_finish_exhaustive,
                        random_mapping)
from repro.core.workload import OUTPUT_DIMS


def small_arch(cols=8):
    return dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=cols)


def small_layer():
    return LayerSpec("l", K=4, C=4, P=8, Q=8, R=3, S=3, pad=1)


@pytest.mark.parametrize("seed", range(10))
def test_analytical_equals_exhaustive(seed):
    m = random_mapping(small_layer(), small_arch(), random.Random(seed),
                       max_steps=512)
    a = generate_analytical(m)
    e = generate_exhaustive(m)
    assert a.equals(e)


def test_output_coverage_and_step_disjointness():
    """Union of all spaces covers the output tensor exactly; spaces of a
    single time step are pairwise disjoint in output coords (each step
    computes distinct output partials per bank)."""
    m = heuristic_mapping(small_layer(), small_arch())
    ds = generate_analytical(m)
    layer = m.layer
    counts = np.zeros((layer.K, layer.P, layer.Q), dtype=np.int64)
    for b in range(ds.n_banks):
        for t in range(ds.n_steps):
            r = ds.rect(b, t)
            counts[r["K"][0]:r["K"][1], r["P"][0]:r["P"][1],
                   r["Q"][0]:r["Q"][1]] += 1
    # every output element visited the same number of times (= number of
    # temporal reduction iterations mapped above the tile)
    assert counts.min() == counts.max() > 0


@given(seed=st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_property_analytical_equals_exhaustive(seed):
    rng = random.Random(seed)
    layer = LayerSpec("l", K=rng.choice([2, 4, 6]), C=rng.choice([2, 3]),
                      P=rng.choice([4, 6]), Q=rng.choice([4, 6]),
                      R=rng.choice([1, 3]), S=rng.choice([1, 3]), pad=1)
    m = random_mapping(layer, small_arch(4), rng, max_steps=256)
    assert generate_analytical(m).equals(generate_exhaustive(m))


@given(seed=st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_property_locate_finish_matches_exhaustive(seed):
    """Analytical point location returns the latest intersecting space —
    the paper's core overlap lemma (Eq 5/6 vs O(N*M) scan)."""
    rng = random.Random(seed)
    layer = LayerSpec("l", K=rng.choice([2, 4]), C=2, P=4, Q=4,
                      R=rng.choice([1, 3]), S=1, pad=0)
    m = random_mapping(layer, small_arch(4), rng, max_steps=256)
    ds = generate_analytical(m)
    for _ in range(5):
        k = rng.randrange(layer.K)
        p = rng.randrange(layer.P)
        q = rng.randrange(layer.Q)
        coords = {d: np.array([v]) for d, v in
                  zip(OUTPUT_DIMS, (k, p, q))}
        bank_a, step_a = locate_finish(m, coords)
        lo = {"K": k, "P": p, "Q": q}
        hi = {"K": k + 1, "P": p + 1, "Q": q + 1}
        bank_e, step_e = locate_finish_exhaustive(ds, lo, hi)
        assert step_a[0] == step_e, (m.pretty(), (k, p, q))


def test_locate_finish_reduction_at_last_iteration():
    """An output coordinate's finish step includes all reduction steps:
    locate_finish must point at the LAST step touching that coordinate."""
    m = heuristic_mapping(small_layer(), small_arch())
    ds = generate_analytical(m)
    coords = {"K": np.array([0]), "P": np.array([0]), "Q": np.array([0])}
    bank, step = locate_finish(m, coords)
    # exhaustive max over intersecting spaces
    _, step_e = locate_finish_exhaustive(
        ds, {"K": 0, "P": 0, "Q": 0}, {"K": 1, "P": 1, "Q": 1})
    assert step[0] == step_e
