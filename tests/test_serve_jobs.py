"""JobQueue edge semantics: staged shutdown, admission control, and
``Job`` invariants.

The happy paths (submit, coalesce, result) are exercised end-to-end in
``test_serve_service.py``; this module pins the corners the service's
correctness leans on — a submit racing ``shutdown``, the
``_finish(exc=...)`` cancellation path, ``Job.completed`` invariants,
depth-gauge consistency after a job fails, and the ``max_pending``
shed/coalesce-while-full rules the HTTP 429 behavior is built from.
"""
import threading

import pytest

from repro.obs import Registry
from repro.serve import Job, JobQueue, QueueFull, QueueShutdown


def test_job_completed_invariants():
    job = Job.completed("k", 42)
    assert job.done()
    assert job.status == "done"
    assert job.n_attached == 1
    assert job.result(timeout=0.1) == 42
    # a done callback registered after completion fires immediately
    seen = []
    job.add_done_callback(lambda j: seen.append(j.key))
    assert seen == ["k"]


def test_job_failure_reraises_and_fires_callbacks():
    q = JobQueue(max_workers=1)
    try:
        seen = []
        job, coalesced = q.submit("boom", lambda: 1 / 0)
        job.add_done_callback(lambda j: seen.append(j.status))
        assert not coalesced
        with pytest.raises(ZeroDivisionError):
            job.result(timeout=5)
        assert job.status == "failed"
        assert seen == ["failed"]
    finally:
        q.shutdown()


def test_depth_gauge_returns_to_zero_after_failure():
    reg = Registry()
    gauge = reg.gauge("serve.queue.depth")
    q = JobQueue(max_workers=1, depth_gauge=gauge)
    try:
        ok, _ = q.submit("ok", lambda: "fine")
        bad, _ = q.submit("bad", lambda: 1 / 0)
        assert ok.result(timeout=5) == "fine"
        with pytest.raises(ZeroDivisionError):
            bad.result(timeout=5)
    finally:
        q.shutdown()
    # failed jobs leave the in-flight table exactly like successes
    assert q.inflight() == 0
    assert gauge.value == 0


def test_coalesce_counts_attachments():
    gate = threading.Event()
    q = JobQueue(max_workers=1)
    try:
        j1, c1 = q.submit("k", lambda: gate.wait(5) and "v")
        j2, c2 = q.submit("k", lambda: "never-runs")
        j3, c3 = q.submit("k", lambda: "never-runs")
        assert (c1, c2, c3) == (False, True, True)
        assert j1 is j2 is j3
        assert j1.n_attached == 3
        assert q.n_coalesced == 2
        gate.set()
        assert j1.result(timeout=5) == "v"
    finally:
        q.shutdown()


def test_max_pending_sheds_but_coalescing_is_exempt():
    gate = threading.Event()
    q = JobQueue(max_workers=1, max_pending=2)
    try:
        blocker, _ = q.submit("blocker", lambda: gate.wait(5))
        # wait until the worker has taken the blocker off the pending
        # queue, so the two fillers below are the only pending entries
        while q.pending() != 0:
            pass
        q.submit("fill-1", lambda: 1)
        q.submit("fill-2", lambda: 2)
        with pytest.raises(QueueFull):
            q.submit("overflow", lambda: 3)
        assert q.n_shed == 1
        # identical-key submissions attach to in-flight jobs without a
        # queue slot — never shed
        j, coalesced = q.submit("fill-1", lambda: 1)
        assert coalesced
        assert q.n_shed == 1
    finally:
        gate.set()
        q.shutdown()
    assert q.inflight() == 0


def test_shutdown_nowait_fails_pending_jobs():
    gate = threading.Event()
    q = JobQueue(max_workers=1)
    running, _ = q.submit("running", lambda: gate.wait(5) and "done")
    while q.pending() != 0 or running.status != "running":
        pass
    queued, _ = q.submit("queued", lambda: "never-runs")
    q.shutdown(wait=False)
    # the queued-but-never-started job fails loudly instead of hanging
    # its waiters (the _finish(exc=...) path)
    with pytest.raises(QueueShutdown):
        queued.result(timeout=5)
    assert queued.status == "failed"
    # the running job still completes on its daemon worker
    gate.set()
    assert running.result(timeout=5) == "done"


def test_submit_racing_shutdown_never_hangs():
    """Hammer submit from one thread while another shuts down: every
    submit either returns a job that terminates (result or
    QueueShutdown) or raises QueueShutdown itself — nothing hangs."""
    q = JobQueue(max_workers=2)
    jobs = []
    errs = []

    def spam():
        for i in range(200):
            try:
                job, _ = q.submit(f"k{i}", lambda i=i: i)
                jobs.append((i, job))
            except QueueShutdown:
                errs.append(i)

    t = threading.Thread(target=spam)
    t.start()
    q.shutdown(wait=False)
    t.join()
    assert len(jobs) + len(errs) == 200
    for i, job in jobs:
        try:
            assert job.result(timeout=5) == i
        except QueueShutdown:
            pass   # cancelled while pending — also a clean termination
    assert q.inflight() == 0


def test_submit_after_shutdown_raises():
    q = JobQueue(max_workers=1)
    q.submit("k", lambda: 1)
    q.shutdown(wait=True)
    with pytest.raises(QueueShutdown):
        q.submit("k2", lambda: 2)


def test_shutdown_wait_drains_everything():
    q = JobQueue(max_workers=2)
    jobs = [q.submit(f"k{i}", lambda i=i: i * i)[0] for i in range(20)]
    q.shutdown(wait=True)
    assert [j.result(timeout=1) for j in jobs] == [i * i for i in range(20)]
    assert q.inflight() == 0
