"""Integration test for the multi-pod dry-run: lower + compile one cell
per shape kind on the 512-device host platform (subprocess — jax locks
the device count on first init)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 512-device lowering + XLA compile

SCRIPT = r'''
from repro.launch.dryrun import lower_cell, run_and_save
import tempfile, json

# decode cell on the multi-pod mesh (fast compile) — proves the "pod"
# axis shards and the cache donation round-trips
rec = lower_cell("whisper_base", "decode_32k", multi_pod=True)
assert rec["n_chips"] == 512, rec["n_chips"]
assert rec["roofline"]["flops"] > 0
assert rec["memory"]["peak_bytes_per_device"] < 16 * 2**30
print("DECODE_CELL_OK")

# train cell single-pod with the dp plan (the hillclimbed config)
rec2 = lower_cell("olmo_1b", "train_4k", multi_pod=False, plan="dp")
assert rec2["roofline"]["bottleneck"] in ("memory", "compute")
assert rec2["roofline"]["collective_s"] < 0.5
print("TRAIN_CELL_OK")

# skip accounting: long_500k must be skipped for a dense arch and run
# for the ssm arch
with tempfile.TemporaryDirectory() as d:
    r = run_and_save("granite_8b", "long_500k", False, d)
    assert str(r["status"]).startswith("skip")
    r2 = run_and_save("mamba2_780m", "long_500k", False, d)
    assert r2["status"] == "ok", r2["status"]
print("SKIP_ACCOUNTING_OK")
'''


@pytest.mark.xfail(
    reason="this container's XLA rematerializes the dp-plan batch sharding "
           "inside the scanned layer stack (spmd_partitioner 'Involuntary "
           "full rematerialization'), making the olmo_1b train_4k cell "
           "collective-bound; the lowering is correct on the XLA the seed "
           "targeted", strict=False)
def test_dryrun_cells():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)  # dryrun sets its own
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    for tag in ("DECODE_CELL_OK", "TRAIN_CELL_OK", "SKIP_ACCOUNTING_OK"):
        assert tag in r.stdout, (tag, r.stdout[-2000:])
