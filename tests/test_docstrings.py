"""Tier-1 mirror of the docstring-coverage gate.

``benchmarks/check_docstrings.py`` is the CI script; this test runs the
same check inside the tier-1 suite so a public DSE/serve name without a
docstring fails locally before it fails in CI. The script is loaded by
file path (not ``sys.path``) so ``benchmarks/`` modules never shadow
test imports.
"""
import importlib.util
import os

_SCRIPT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "check_docstrings.py")


def _load_checker():
    spec = importlib.util.spec_from_file_location("check_docstrings",
                                                  _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_public_api_docstring_coverage():
    checker = _load_checker()
    gaps = checker.missing_docstrings(checker.MODULES)
    assert not gaps, (
        "public names lack docstrings (see benchmarks/"
        "check_docstrings.py):\n  " + "\n  ".join(gaps))
