"""Trace analytics tests (repro.obs.profile) and the obs-profile CLI.

Covers the parser's call-tree reconstruction (exit-order + per-thread
depth adoption, sampled-out parents, old-format traces without
``ts0``/``tid``), the attribution invariant (self times sum to the
root total), the Chrome trace-event and folded-stack exports, and the
graceful handling of empty/truncated/missing trace files the CLI
relies on.
"""
import json
import subprocess
import sys
import os

import pytest

from repro import obs
from repro.obs import profile as pr


@pytest.fixture(autouse=True)
def _obs_clean():
    obs.disable()
    yield
    obs.disable()


def _write_trace(path, events):
    with open(path, "w", encoding="utf-8") as fh:
        for ev in events:
            fh.write(json.dumps(ev) + "\n")


def _span(name, ts0, dur, depth, tid=1, **attrs):
    ev = {"ev": "span", "name": name, "ts": ts0 + dur, "ts0": ts0,
          "dur_s": dur, "depth": depth, "tid": tid}
    ev.update(attrs)
    return ev


def test_parse_trace_rebuilds_nesting(tmp_path):
    # exit order: children before parents (spans are written at exit)
    path = str(tmp_path / "t.jsonl")
    _write_trace(path, [
        _span("leaf_a", 10.0, 1.0, 1),
        _span("leaf_b", 11.5, 0.5, 1),
        _span("root", 10.0, 3.0, 0),
    ])
    t = pr.parse_trace(path)
    assert t.n_spans == 3 and t.n_bad_lines == 0
    assert [r.name for r in t.roots] == ["root"]
    root = t.roots[0]
    assert [c.name for c in root.children] == ["leaf_a", "leaf_b"]
    assert root.self_s() == pytest.approx(1.5)
    assert t.total_s() == pytest.approx(3.0)


def test_parse_trace_threads_do_not_cross(tmp_path):
    path = str(tmp_path / "t.jsonl")
    _write_trace(path, [
        _span("w1.leaf", 0.0, 1.0, 1, tid=1),
        _span("w2.leaf", 0.0, 2.0, 1, tid=2),
        _span("w1.root", 0.0, 1.5, 0, tid=1),
        _span("w2.root", 0.0, 2.5, 0, tid=2),
    ])
    t = pr.parse_trace(path)
    assert sorted(r.name for r in t.roots) == ["w1.root", "w2.root"]
    for r in t.roots:
        assert len(r.children) == 1
        assert r.children[0].name.split(".")[0] == r.name.split(".")[0]


def test_parse_trace_sampled_out_parent_flattens(tmp_path):
    # depth-2 leaves whose depth-1 parent was sampled away attach to
    # the depth-0 root instead of vanishing
    path = str(tmp_path / "t.jsonl")
    _write_trace(path, [
        _span("deep", 0.0, 1.0, 2),
        _span("root", 0.0, 4.0, 0),
    ])
    t = pr.parse_trace(path)
    assert [c.name for c in t.roots[0].children] == ["deep"]
    assert t.roots[0].self_s() == pytest.approx(3.0)


def test_parse_trace_old_format_and_junk_lines(tmp_path):
    # pre-ts0 traces (no start timestamp, no tid) still parse; junk
    # lines and non-span events are counted/skipped, never fatal
    path = str(tmp_path / "t.jsonl")
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(json.dumps({"ev": "span", "name": "old", "ts": 100.0,
                             "dur_s": 2.0, "depth": 0}) + "\n")
        fh.write(json.dumps({"ev": "event", "name": "mark"}) + "\n")
        fh.write("{this is not json\n")
        fh.write('{"ev": "span", "name": "trunc', )  # torn tail
    t = pr.parse_trace(path)
    assert t.n_spans == 1 and t.n_bad_lines == 2
    node = t.roots[0]
    assert node.ts0 == pytest.approx(98.0)      # ts - dur_s fallback
    assert node.tid == 0


def test_parse_trace_missing_and_empty_files(tmp_path):
    t = pr.parse_trace(str(tmp_path / "nope.jsonl"))
    assert t.n_spans == 0 and t.roots == []
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    t = pr.parse_trace(str(empty))
    assert t.n_spans == 0
    assert "no spans" in pr.render_profile(t)


def test_attribution_self_times_sum_to_root_total(tmp_path):
    trace_path = str(tmp_path / "t.jsonl")
    obs.enable(trace_path=trace_path)
    with obs.span("root"):
        with obs.span("phase_a"):
            with obs.span("inner"):
                pass
        with obs.span("phase_b"):
            pass
    obs.disable()
    t = pr.parse_trace(trace_path)
    rows = pr.attribution(t)
    total_self = sum(r["self_s"] for r in rows)
    # the acceptance bar: per-name self times sum to the root span's
    # duration within 1%
    assert total_self == pytest.approx(t.total_s(), rel=0.01)
    assert sum(r["self_pct"] for r in rows) == pytest.approx(100.0,
                                                             rel=0.01)
    assert {r["name"] for r in rows} \
        == {"root", "phase_a", "phase_b", "inner"}


def test_critical_path_descends_longest_child(tmp_path):
    path = str(tmp_path / "t.jsonl")
    _write_trace(path, [
        _span("short", 0.0, 1.0, 1),
        _span("long", 1.0, 3.0, 1),
        _span("long.leaf", 1.0, 2.0, 2),
        _span("root", 0.0, 5.0, 0),
    ])
    # exit order above is wrong for nesting (long.leaf exits after
    # long) — rewrite in true exit order
    _write_trace(path, [
        _span("short", 0.0, 1.0, 1),
        _span("long.leaf", 1.0, 2.0, 2),
        _span("long", 1.0, 3.0, 1),
        _span("root", 0.0, 5.0, 0),
    ])
    steps = pr.critical_path(pr.parse_trace(path))
    assert [s["name"] for s in steps] == ["root", "long", "long.leaf"]


def test_chrome_trace_export_shape(tmp_path):
    path = str(tmp_path / "t.jsonl")
    _write_trace(path, [
        _span("leaf", 100.5, 0.25, 1, tid=7, net="resnet18"),
        _span("root", 100.0, 1.0, 0, tid=7),
    ])
    doc = pr.chrome_trace(pr.parse_trace(path))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for e in evs:
        assert e["ph"] == "X" and e["pid"] == 1 and e["tid"] == 7
        assert e["ts"] >= 0 and e["dur"] > 0
    leaf = next(e for e in evs if e["name"] == "leaf")
    assert leaf["ts"] == pytest.approx(0.5e6)       # µs after root start
    assert leaf["dur"] == pytest.approx(0.25e6)
    assert leaf["args"] == {"net": "resnet18"}
    json.dumps(doc)                                  # valid JSON


def test_folded_stacks_cover_every_microsecond(tmp_path):
    path = str(tmp_path / "t.jsonl")
    _write_trace(path, [
        _span("leaf", 0.0, 0.4, 1),
        _span("root", 0.0, 1.0, 0),
    ])
    lines = pr.folded_stacks(pr.parse_trace(path))
    parsed = dict(line.rsplit(" ", 1) for line in lines)
    assert parsed == {"root": "600000", "root;leaf": "400000"}


def test_render_profile_table(tmp_path):
    path = str(tmp_path / "t.jsonl")
    _write_trace(path, [
        _span("leaf", 0.0, 0.4, 1),
        _span("root", 0.0, 1.0, 0),
    ])
    text = pr.render_profile(pr.parse_trace(path), top=5)
    assert "critical path:" in text
    assert "root" in text and "leaf" in text
    assert "100.0%" in text                  # (shown) covers everything


def _run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(cwd, "src")
    return subprocess.run(
        [sys.executable, os.path.join(cwd, "benchmarks", "run.py")]
        + args, capture_output=True, text=True, env=env, cwd=cwd)


def test_obs_profile_cli_end_to_end(tmp_path):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    trace = str(tmp_path / "t.jsonl")
    _write_trace(trace, [
        _span("leaf", 0.0, 0.4, 1),
        _span("root", 0.0, 1.0, 0),
    ])
    chrome = str(tmp_path / "chrome.json")
    folded = str(tmp_path / "folded.txt")
    r = _run_cli(["obs-profile", "--trace", trace, "--chrome-out",
                  chrome, "--folded-out", folded], repo)
    assert r.returncode == 0, r.stderr
    assert "critical path:" in r.stdout
    doc = json.load(open(chrome, encoding="utf-8"))
    assert len(doc["traceEvents"]) == 2
    assert open(folded, encoding="utf-8").read().strip()


def test_obs_profile_cli_missing_and_truncated(tmp_path):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = _run_cli(["obs-profile", "--trace",
                  str(tmp_path / "nope.jsonl")], repo)
    assert r.returncode == 2
    assert "no trace" in r.stderr
    trunc = tmp_path / "trunc.jsonl"
    trunc.write_text('{"ev": "span", "name": "cut')
    r = _run_cli(["obs-profile", "--trace", str(trunc)], repo)
    assert r.returncode == 0, r.stderr
    assert "no spans" in r.stdout


def test_obs_report_cli_corrupt_snapshot(tmp_path):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    r = _run_cli(["obs-report", "--metrics", str(bad)], repo)
    assert r.returncode == 2
    assert "not a metrics snapshot" in r.stderr
    empty = tmp_path / "empty.json"
    empty.write_text("")
    r = _run_cli(["obs-report", "--metrics", str(empty)], repo)
    assert r.returncode == 2
    lst = tmp_path / "list.json"
    lst.write_text("[1, 2]")
    r = _run_cli(["obs-report", "--metrics", str(lst)], repo)
    assert r.returncode == 2
    assert "JSON object" in r.stderr
