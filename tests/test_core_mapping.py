"""Unit tests: workloads, architectures, mapping representation."""
import random

import pytest

from repro.core import (DIMS, LayerSpec, dram_pim, get_network,
                        heuristic_mapping, random_mapping, reram_pim)
from repro.core.mapping import divisors


def small_arch():
    return dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=16)


def small_layer():
    return LayerSpec("l", K=8, C=4, P=12, Q=12, R=3, S=3, pad=1)


def test_networks_shapes():
    r18 = get_network("resnet18")
    assert len(r18) == 20
    assert r18[0].C == 3 and r18[0].stride == 2
    assert len(get_network("vgg16")) == 13
    r50 = get_network("resnet50")
    assert len(r50) == 49
    # chain consistency: consumer C == producer K for conv chains
    for net in ("vgg16",):
        layers = get_network(net)
        for a, b in zip(layers, layers[1:]):
            assert b.C == a.K


def test_layer_derived_quantities():
    l = small_layer()
    assert l.macs == 8 * 4 * 12 * 12 * 9
    assert l.input_shape == (4, 14, 14)
    assert l.output_size() == 12 * 12 * 8
    assert l.overall_size() == 12 * 12 * 4 * 8


def test_divisors():
    assert divisors(12) == (1, 2, 3, 4, 6, 12)
    assert divisors(1) == (1,)
    assert divisors(7) == (1, 7)


def test_heuristic_mapping_valid():
    m = heuristic_mapping(small_layer(), small_arch())
    m.validate()
    assert m.n_banks <= 4
    assert m.n_columns_used <= 16
    # full factorization -> macs conserved
    assert m.macs_per_step() * m.n_steps * m.n_banks == small_layer().macs


@pytest.mark.parametrize("seed", range(8))
def test_random_mapping_valid(seed):
    rng = random.Random(seed)
    layer = small_layer()
    arch = small_arch()
    m = random_mapping(layer, arch, rng, max_steps=4096)
    m.validate()
    assert m.n_steps <= 4096
    assert m.macs_per_step() * m.n_steps * m.n_banks == layer.macs


def test_time_strides_mixed_radix():
    m = heuristic_mapping(small_layer(), small_arch())
    # strides are a proper mixed radix: stride[i] = prod sizes inner to i
    sizes = [lp.size for lp in m.time_loops]
    strides = m.time_strides
    acc = 1
    for sz, st in zip(reversed(sizes), reversed(strides)):
        assert st == acc
        acc *= sz


def test_arch_presets():
    d = dram_pim()
    assert d.n_target_instances == 16
    assert d.columns_per_target == 8192
    assert d.op_latency("add") == 196.0
    assert d.op_latency("mul") == 980.0
    r = reram_pim()
    assert r.op_latency("add") == 442.0
    # AAP fallback model when ops not pinned
    bare = dram_pim()
    object.__setattr__(bare.levels[-1], "pim_ops", None)
    assert bare.op_latency("add") == (4 * 16 + 1) * bare.timing.t_aap


def test_reduction_dims_never_spatial_above_target():
    rng = random.Random(0)
    arch = small_arch()
    for s in range(20):
        m = random_mapping(small_layer(), arch, random.Random(s), 4096)
        for li, lp in m.nest:
            if lp.spatial and lp.dim in ("C", "R", "S"):
                assert li == arch.target_index
