"""End-to-end behaviour tests for the Fast-OverlaPIM system."""
import numpy as np
import pytest

from repro.core import (SearchConfig, describe, dram_pim, evaluate_chain,
                        optimize_network, reram_pim)


@pytest.fixture(scope="module")
def arch():
    # reduced column count keeps layers small enough for fast CI
    return dram_pim(channels_per_layer=2, banks_per_channel=4,
                    columns_per_bank=1024)


def run(net, arch, mode, strategy="forward", n=10, seed=0):
    desc = describe(net)
    cfg = SearchConfig(n_candidates=n, seed=seed, max_steps=2048,
                       mode=mode, strategy=strategy)
    return optimize_network(desc.layers, desc.edges, arch, cfg)


def test_resnet18_transform_beats_original(arch):
    ro = run("resnet18", arch, "original")
    rt = run("resnet18", arch, "transform")
    assert rt.total_ns < ro.total_ns  # the paper's headline direction
    assert len(rt.layers) == 20


def test_vgg16_modes_ordering(arch):
    ro = run("vgg16", arch, "original")
    rv = run("vgg16", arch, "overlap")
    rt = run("vgg16", arch, "transform")
    assert rt.total_ns <= rv.total_ns * 1.02
    assert rv.total_ns <= ro.total_ns * 1.02


def test_original_overlap_evaluation(arch):
    """'Best Original Overlap': Timeloop-best mappings re-scored with
    overlap never get slower (Fig 4 motivation)."""
    desc = describe("resnet18")
    ro = run("resnet18", arch, "original")
    maps = [l.mapping for l in ro.layers]
    boo = evaluate_chain(maps, desc.edges, "overlap")
    assert boo.total_ns <= ro.total_ns + 1e-6


def test_bert_encoder_end_to_end(arch):
    rt = run("bert_encoder", arch, "transform")
    ro = run("bert_encoder", arch, "original")
    assert rt.total_ns <= ro.total_ns * 1.02


def test_reram_end_to_end():
    arch = reram_pim(tiles_per_layer=2, blocks_per_tile=4,
                     columns_per_block=256)
    rt = run("resnet18", arch, "transform", n=6)
    ro = run("resnet18", arch, "original", n=6)
    assert rt.total_ns <= ro.total_ns * 1.02


def test_per_layer_latencies_positive(arch):
    rt = run("vgg16", arch, "transform", n=6)
    assert all(l.latency_ns > 0 for l in rt.layers)
    assert all(np.isfinite(l.end_ns) for l in rt.layers)
