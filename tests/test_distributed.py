"""Distributed correctness on an 8-device host mesh (subprocess sets
XLA_FLAGS before jax import via conftest-free isolation)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 8-device subprocess + XLA compiles

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

# ---- sharded train step == single-device train step ----
from repro.configs import get_config
from repro.models import model_zoo
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import param_specs, opt_specs, to_shardings, batch_specs
from repro.launch import steps as steps_lib
from repro.train.optimizer import init_opt_state
from repro.models.inputs import make_train_batch

cfg = get_config("olmo_1b", smoke=True)
batch = make_train_batch(cfg, 8, 32, seed=3)
params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
opt = init_opt_state(params)
step = steps_lib.make_train_step(cfg)

# single device
p1, o1, m1 = jax.jit(step)(params, opt, batch)

# 4x2 mesh
mesh = make_host_mesh(data=4, model=2)
pspecs = param_specs(params, mesh)
oshard = to_shardings({"mu": opt_specs(pspecs, params, mesh),
                       "nu": opt_specs(pspecs, params, mesh),
                       "step": P()}, mesh)
pshard = to_shardings(pspecs, mesh)
bshard = to_shardings(batch_specs(cfg, 8, mesh, "train"), mesh)
with mesh:
    p2, o2, m2 = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None))(params, opt, batch)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (m1["loss"], m2["loss"])
for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               rtol=2e-3, atol=2e-3)
print("TRAIN_OK")

# ---- pipeline forward == sequential reference ----
from repro.pipeline.overlap_pipeline import pipeline_forward, sequential_reference, overlap_schedule
mesh2 = jax.make_mesh((4,), ("stage",))
def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])
k = jax.random.PRNGKey(1)
sp = {"w": jax.random.normal(k, (4, 16, 16)) * 0.5}
x = jax.random.normal(jax.random.PRNGKey(2), (6, 3, 16))
y = pipeline_forward(stage_fn, sp, x, mesh2, axis="stage")
yref = sequential_reference(stage_fn, sp, x)
np.testing.assert_allclose(np.asarray(y), np.asarray(yref), rtol=1e-5, atol=1e-5)
# with a transformation-derived emission order
order = overlap_schedule(np.array([5.0, 1.0, 3.0, 0.0, 4.0, 2.0]))
y2 = pipeline_forward(stage_fn, sp, x, mesh2, axis="stage", order=order)
np.testing.assert_allclose(np.asarray(y2), np.asarray(yref), rtol=1e-5, atol=1e-5)
print("PIPELINE_OK")

# ---- decode parity: sharded decode == unsharded decode ----
from repro.launch.sharding import cache_specs
cfg2 = get_config("granite_8b", smoke=True)
params2 = model_zoo.init_params(cfg2, jax.random.PRNGKey(5))
cache = model_zoo.init_cache(cfg2, 8, 64)
toks = jnp.arange(8, dtype=jnp.int32) % cfg2.vocab
dstep = steps_lib.make_decode_step(cfg2)
l1, c1 = jax.jit(dstep)(params2, cache, toks)
cspecs = cache_specs(cfg2, 8, mesh, cache)
with mesh:
    l2, c2 = jax.jit(dstep,
        in_shardings=(to_shardings(param_specs(params2, mesh), mesh),
                      to_shardings(cspecs, mesh),
                      NamedSharding(mesh, batch_specs(cfg2, 8, mesh, "decode"))))(
        params2, cache, toks)
# bf16 reassociation across shards: compare loosely + same argmax
np.testing.assert_allclose(np.asarray(l1, np.float32), np.asarray(l2, np.float32),
                           rtol=5e-2, atol=5e-2)
assert (np.argmax(np.asarray(l1, np.float32), -1)
        == np.argmax(np.asarray(l2, np.float32), -1)).all()
print("DECODE_OK")

# ---- elastic re-mesh: checkpoint on a 4x2 mesh, restore onto 2x4 ----
import tempfile
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import init_opt_state as _init_opt
with tempfile.TemporaryDirectory() as td:
    opt0 = _init_opt(params)
    ckpt_lib.save(td, 5, {"params": params, "opt": opt0},
                  meta={"mesh": [4, 2]})
    mesh_b = make_host_mesh(data=2, model=4)
    pspecs_b = param_specs(params, mesh_b)
    pshard_b = to_shardings(pspecs_b, mesh_b)
    oshard_b = to_shardings({"mu": opt_specs(pspecs_b, params, mesh_b),
                             "nu": opt_specs(pspecs_b, params, mesh_b),
                             "step": P()}, mesh_b)
    res = ckpt_lib.restore(td, {"params": jax.eval_shape(lambda: params),
                                "opt": jax.eval_shape(lambda: opt0)},
                           {"params": pshard_b, "opt": oshard_b})
    assert res is not None and res[0] == 5
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(res[1]["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
print("ELASTIC_OK")
'''


def test_distributed_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    for tag in ("TRAIN_OK", "PIPELINE_OK", "DECODE_OK", "ELASTIC_OK"):
        assert tag in r.stdout, (tag, r.stdout, r.stderr[-2000:])
