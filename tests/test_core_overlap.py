"""Overlap analysis: analytical == exhaustive (paper C2), scheduling,
transformation (C3)."""
import random

import numpy as np
import pytest

try:  # property tests prefer hypothesis; fall back to fixed seeded draws
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _prop_fallback import given, settings, st

from repro.core import (Edge, HeadFoldMap, HeadUnfoldMap, IdentityMap,
                        LayerSpec, WeightMap, analyze, chain_edges, describe,
                        dram_pim, evaluate_chain, heuristic_mapping, matmul,
                        overlapped_end, random_mapping,
                        ready_steps_analytical, ready_steps_exhaustive,
                        schedule_with_ready, transform_schedule)


def small_arch(cols=8):
    return dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=cols)


def pair(seed, P=6, Q=6, C1=2, K1=4, K2=4, R=3):
    rng = random.Random(seed)
    lp = LayerSpec("p", K=K1, C=C1, P=P, Q=Q, R=R, S=R, pad=R // 2)
    lc = LayerSpec("c", K=K2, C=K1, P=P, Q=Q, R=R, S=R, pad=R // 2)
    arch = small_arch(4)
    mp = random_mapping(lp, arch, rng, max_steps=256)
    mc = random_mapping(lc, arch, rng, max_steps=256)
    return mp, mc


@pytest.mark.parametrize("seed", range(6))
def test_ready_analytical_equals_exhaustive(seed):
    mp, mc = pair(seed)
    sa, ra = ready_steps_analytical(mp, mc)
    se, re = ready_steps_exhaustive(mp, mc)
    assert np.array_equal(ra, re)
    assert np.array_equal(sa[~ra], se[~ra])


@given(seed=st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_property_ready_steps(seed):
    rng = random.Random(seed)
    mp, mc = pair(seed, P=rng.choice([4, 6]), Q=4,
                  K1=rng.choice([2, 4]), K2=2, R=rng.choice([1, 3]))
    sa, ra = ready_steps_analytical(mp, mc)
    se, re = ready_steps_exhaustive(mp, mc)
    assert np.array_equal(sa[~ra], se[~re])


def test_stride2_and_padding_edges():
    """Strided consumer + padding: edge spaces may be ready at t=0."""
    lp = LayerSpec("p", K=4, C=2, P=8, Q=8, R=3, S=3, pad=1)
    lc = LayerSpec("c", K=2, C=4, P=4, Q=4, R=3, S=3, stride=2, pad=1)
    arch = small_arch(4)
    rng = random.Random(7)
    mp = random_mapping(lp, arch, rng, 256)
    mc = random_mapping(lc, arch, rng, 256)
    sa, ra = ready_steps_analytical(mp, mc)
    se, re = ready_steps_exhaustive(mp, mc)
    assert np.array_equal(sa[~ra], se[~re])


def test_schedule_with_ready_recurrence():
    """Closed form == explicit recurrence."""
    rng = np.random.RandomState(0)
    ready = rng.uniform(0, 100, size=(3, 17))
    L = 7.0
    fin = schedule_with_ready(ready, L)
    for b in range(3):
        end = 0.0
        for t in range(17):
            end = max(end, ready[b, t]) + L
            assert fin[b, t] == pytest.approx(end)


def test_overlap_improves_or_equals_sequential():
    mp, mc = pair(3)
    pp, pc = analyze(mp), analyze(mc)
    fin_step = (np.arange(mp.n_steps) + 1.0) * pp.step_ns
    step, r0 = ready_steps_analytical(mp, mc)
    ready = np.where(r0, 0.0, fin_step[step] + pp.tile_move_ns)
    end_overlap = overlapped_end(ready, pc.step_ns)
    end_seq = pp.compute_ns + pc.compute_ns
    assert end_overlap <= end_seq + pp.tile_move_ns + 1e-6


def test_transform_never_worse_than_plain_overlap():
    """Round-robin re-allocation by ready time is at least as good as the
    original allocation when relocation is free, and valid otherwise."""
    mp, mc = pair(5)
    pp, pc = analyze(mp), analyze(mc)
    fin_step = (np.arange(mp.n_steps) + 1.0) * pp.step_ns
    step, r0 = ready_steps_analytical(mp, mc)
    ready = np.where(r0, 0.0, fin_step[step])
    tr = transform_schedule(ready, pc.step_ns, tile_move_ns=0.0)
    assert tr.end_ns <= overlapped_end(ready, pc.step_ns) + 1e-6
    assert 0.0 <= tr.moved_frac <= 1.0
    # finish array covers every original space exactly once
    assert tr.finish_ns.shape == ready.shape
    assert np.all(tr.finish_ns > 0)


def test_transform_respects_ready_times():
    ready = np.array([[0.0, 50.0, 10.0, 90.0]])
    tr = transform_schedule(ready, step_ns=5.0)
    # each space finishes at least ready + one step after its ready time
    assert np.all(tr.finish_ns >= ready + 5.0 - 1e-9)


def test_transform_sorted_ready_balances_banks():
    """n equal-ready spaces over b banks finish in ceil(n/b) steps."""
    ready = np.zeros((2, 8))  # 16 spaces, all ready at 0
    tr = transform_schedule(ready, step_ns=1.0)
    assert tr.end_ns == pytest.approx(8.0)


# ---------------------------------------------------------------------------
# CoordMap coverage: analytical == exhaustive for the attention maps
# (HeadFold / HeadUnfold / both WeightMap kinds), not just IdentityMap.
# ---------------------------------------------------------------------------

SEQ, HEADS, HD, DM = 8, 2, 4, 8


def _attn_pair(kind, seed):
    """(producer layer, consumer layer, cmap) for one attention edge."""
    rng = random.Random(seed)
    proj = matmul("proj", SEQ, DM, DM)
    qk = matmul("qk", SEQ, HD, SEQ, batch=HEADS)
    av = matmul("av", SEQ, SEQ, HD, batch=HEADS)
    out = matmul("out", SEQ, DM, DM)
    pairs = {
        "headfold": (proj, qk, HeadFoldMap(SEQ, HD)),     # qk <- q_proj
        "headunfold": (av, out, HeadUnfoldMap(SEQ, HD)),  # out <- av
        "qk_weight": (proj, qk, WeightMap(SEQ, HD, "qk_weight")),
        "av_weight": (proj, av, WeightMap(SEQ, HD, "av_weight")),
    }
    lp, lc, cmap = pairs[kind]
    arch = small_arch(8)
    mp = random_mapping(lp, arch, rng, 64)
    mc = random_mapping(lc, arch, rng, 64)
    return mp, mc, cmap


@pytest.mark.parametrize("kind",
                         ["headfold", "headunfold", "qk_weight",
                          "av_weight"])
@pytest.mark.parametrize("seed", range(4))
def test_attention_cmaps_analytical_equals_exhaustive(kind, seed):
    mp, mc, cmap = _attn_pair(kind, seed)
    sa, ra = ready_steps_analytical(mp, mc, cmap)
    se, re = ready_steps_exhaustive(mp, mc, cmap)
    assert np.array_equal(ra, re)
    assert np.array_equal(sa[~ra], se[~ra])


@pytest.mark.parametrize("seed", range(3))
def test_bert_network_edges_analytical_equals_exhaustive(seed):
    """Every edge of the wired BERT encoder block, as built by
    ``describe`` (covers the conservative head-boundary bounding boxes —
    DESIGN.md Section 5.3)."""
    desc = describe("bert_encoder", seq=SEQ, d_model=DM, heads=HEADS,
                    d_ff=16)
    arch = small_arch(8)
    rng = random.Random(seed)
    maps = [random_mapping(l, arch, rng, 64) for l in desc.layers]
    for i, edges in enumerate(desc.edges):
        for e in edges:
            sa, ra = ready_steps_analytical(maps[e.producer], maps[i],
                                            e.cmap)
            se, re = ready_steps_exhaustive(maps[e.producer], maps[i],
                                            e.cmap)
            assert np.array_equal(ra, re), (i, e.producer)
            assert np.array_equal(sa[~ra], se[~ra]), (i, e.producer)


# ---------------------------------------------------------------------------
# Exhaustive-path sentinel regression: a consumer space whose projected
# rectangle intersects NO producer space (e.g. a channel overhang, where the
# consumer reads more input channels than the producer computes) must come
# out ready-at-0, not carrying the -1 search sentinel — ``fin_step[step]``
# would wrap -1 to the LAST producer step and charge the space "ready at
# producer completion".
# ---------------------------------------------------------------------------

def _overhang_pair(seed=0):
    """Consumer C=8 > producer K=4: tiles with C-offset >= 4 project to
    producer-K intervals beyond the producer's output range."""
    lp = LayerSpec("p", K=4, C=2, P=6, Q=6, R=3, S=3, pad=1)
    lc = LayerSpec("c", K=4, C=8, P=6, Q=6, R=3, S=3, pad=1)
    arch = dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=4)
    rng = random.Random(seed)
    mp = random_mapping(lp, arch, rng, 256)
    mc = random_mapping(lc, arch, rng, 256)
    return lp, mp, mc


def test_exhaustive_no_intersection_is_ready_at_zero():
    from repro.core.overlap import consumer_tiles

    lp, mp, mc = _overhang_pair(0)
    lo, hi = consumer_tiles(mc)
    plo, _phi, r0 = IdentityMap().to_producer(lp, mc.layer, lo, hi)
    none = (plo["K"] >= lp.K) & ~r0
    assert none.any()   # the scenario actually occurs in this pair
    step, ready0 = ready_steps_exhaustive(mp, mc)
    # pre-fix: step[none] == -1 and ready0[none] stayed False
    assert step.min() >= 0
    assert ready0[none].all()
    # intersecting spaces are untouched by the clamp
    sa, ra = ready_steps_analytical(mp, mc)
    both = ~ready0 & ~ra
    assert np.array_equal(step[both], sa[both])


def test_exhaustive_sentinel_spaces_not_charged_producer_completion():
    """Scheduling consequence of the fix: the overhang spaces must not
    inherit the producer's last finish time through index wraparound."""
    _lp, mp, mc = _overhang_pair(0)
    pp, pc = analyze(mp), analyze(mc)
    fin_step = (np.arange(mp.n_steps) + 1.0) * pp.step_ns
    step, r0 = ready_steps_exhaustive(mp, mc)
    ready = np.where(r0, 0.0, fin_step[step] + pp.tile_move_ns)
    none_ready = ready[r0]
    assert np.all(none_ready == 0.0)
    # and the resulting schedule is no worse than the pre-fix wraparound
    wrap = np.where(r0, fin_step[-1], ready)
    assert (overlapped_end(ready, pc.step_ns)
            <= overlapped_end(wrap, pc.step_ns) + 1e-9)


# ---------------------------------------------------------------------------
# digit_scan property coverage: the m == 1 fast path must agree with the
# general multi-digit scan and with brute-force interval enumeration.
# ---------------------------------------------------------------------------

def _digit_brute(loops, lo, hi):
    xs = np.arange(lo, hi + 1)
    tot = np.zeros(xs.shape)
    for n, blk, w in loops:
        tot = tot + float(w) * ((xs // blk) % n)
    return float(tot.max())


@given(seed=st.integers(0, 10 ** 6))
@settings(max_examples=30, deadline=None)
def test_property_digit_scan_single_loop_fast_path(seed):
    from repro.core.overlap import digit_scan

    rng = random.Random(seed)
    n1 = rng.choice([2, 3, 4, 5, 8])
    blk = rng.choice([1, 2, 3, 4])
    w1 = rng.choice([0, 1, 3, 7])
    dim = n1 * blk
    lo = rng.randrange(dim)
    hi = rng.randrange(lo, dim)
    loops = [(n1, blk, w1)]
    los = np.array([lo])
    his = np.array([hi])
    fast = digit_scan(loops, los, his)          # m == 1 branch
    # size-1 dummy loop contributes 0 everywhere but forces the general
    # multi-digit path over the same interval
    general = digit_scan(loops + [(1, 1, 0)], los, his)
    assert float(fast[0]) == float(general[0])
    assert float(fast[0]) == _digit_brute(loops, lo, hi)


@given(seed=st.integers(0, 10 ** 6))
@settings(max_examples=30, deadline=None)
def test_property_digit_scan_multi_loop_vs_brute(seed):
    from repro.core.overlap import digit_scan

    rng = random.Random(seed)
    m = rng.choice([2, 3])
    sizes = [rng.choice([2, 3, 4]) for _ in range(m)]
    # mixed-radix decomposition of the dim: loop j owns blocks of the
    # product of the sizes inside it; like rect_loops, the list is
    # outermost (most significant digit) first — the scan's prefix /
    # suffix families rely on that ordering
    blks, b = [], 1
    for sz in sizes:
        blks.append(b)
        b *= sz
    dim = b
    loops = [(sz, blk, rng.choice([0, 1, 2, 5]))
             for sz, blk in zip(sizes, blks)][::-1]
    lo = rng.randrange(dim)
    hi = rng.randrange(lo, dim)
    got = digit_scan(loops, np.array([lo]), np.array([hi]))
    assert float(got[0]) == _digit_brute(loops, lo, hi)


@pytest.mark.parametrize("seed", range(4))
def test_mode_ordering_on_fixed_chain(seed):
    """transform <= overlap <= original total_ns for the same mappings on
    a fixed seeded chain (Fig 4 / Fig 10 trend as an invariant)."""
    net = [
        LayerSpec("l0", K=8, C=4, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l1", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l2", K=16, C=8, P=4, Q=4, R=3, S=3, stride=2, pad=1),
    ]
    arch = dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=64)
    rng = random.Random(seed)
    maps = [random_mapping(l, arch, rng, 512) for l in net]
    edges = chain_edges(net)
    t = {m: evaluate_chain(maps, edges, m).total_ns
         for m in ("original", "overlap", "transform")}
    assert t["transform"] <= t["overlap"] + 1e-6
    assert t["overlap"] <= t["original"] + 1e-6
