def pytest_report_header(config):
    return ("marker hint: run `-m 'not kernels and not slow'` for the fast "
            "core loop; default runs everything (markers in pytest.ini)")
