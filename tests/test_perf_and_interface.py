"""Coverage: PIM performance/energy model invariants, network interface
edge wiring (pool inference, residuals), config registry."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_status, cells, get_config
from repro.core import (LayerSpec, analyze, describe, dram_pim,
                        heuristic_mapping, reram_pim, step_latency_ns)
from repro.core.interface import _pool_between


# -- perf model ---------------------------------------------------------------

def small_arch(cols=256):
    return dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=cols)


def test_step_latency_positive_and_scales_with_work():
    l_small = LayerSpec("s", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1)
    l_big = LayerSpec("b", K=16, C=16, P=16, Q=16, R=3, S=3, pad=1)
    m1 = heuristic_mapping(l_small, small_arch(), 4096)
    m2 = heuristic_mapping(l_big, small_arch(), 4096)
    p1, p2 = analyze(m1), analyze(m2)
    assert p1.compute_ns > 0
    assert p2.compute_ns > p1.compute_ns  # 16x the MACs
    # MAC conservation through the decomposition
    assert m1.macs_per_step() * m1.n_steps * m1.n_banks == l_small.macs


def test_more_columns_is_faster():
    l = LayerSpec("l", K=16, C=16, P=16, Q=16, R=3, S=3, pad=1)
    slow = analyze(heuristic_mapping(l, small_arch(64), 4096))
    fast = analyze(heuristic_mapping(l, small_arch(1024), 4096))
    assert fast.compute_ns < slow.compute_ns


def test_energy_accounting():
    l = LayerSpec("l", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1)
    p = analyze(heuristic_mapping(l, small_arch(), 4096))
    # bit-serial MAC energy: (n+1) adds of (4n+1) AAPs each
    arch = small_arch()
    n = arch.word_bits
    per_mac = (n + 1) * (4 * n + 1) * arch.timing.e_act
    assert p.energy_pj >= l.macs * per_mac


def test_reram_latency_constants_differ_from_dram():
    l = LayerSpec("l", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1)
    d = step_latency_ns(heuristic_mapping(l, dram_pim(
        channels_per_layer=2, banks_per_channel=2,
        columns_per_bank=256), 4096))
    r = step_latency_ns(heuristic_mapping(l, reram_pim(
        tiles_per_layer=2, blocks_per_tile=2,
        columns_per_block=256), 4096))
    assert d != r  # 196/980 vs 442/696 op latencies


# -- interface / edges --------------------------------------------------------

def test_pool_inference_vgg():
    layers = describe("vgg16").layers
    # conv2 (224) -> conv3 (112): pool 2 between blocks
    assert _pool_between(layers[1], layers[2]) == 2
    # within a block: no pool
    assert _pool_between(layers[2], layers[3]) == 1


def test_resnet18_residual_edges():
    desc = describe("resnet18")
    by_name = {l.name: i for i, l in enumerate(desc.layers)}
    # the block after an add consumes both main and downsample paths
    i = by_name["s2b1c1"]
    prods = {e.producer for e in desc.edges[i]}
    assert by_name["s2b0c2"] in prods and by_name["s2b0ds"] in prods
    # downsample consumes the stage input, not its neighbor
    ds = by_name["s2b0ds"]
    assert desc.edges[ds][0].producer == by_name["s1b1c2"]
    # edges always point backward (searchable order)
    for i, es in enumerate(desc.edges):
        assert all(e.producer < i for e in es)


def test_stem_pool_resnet():
    layers = describe("resnet18").layers
    assert _pool_between(layers[0], layers[1]) == 2  # maxpool after conv1


# -- config registry ----------------------------------------------------------

def test_all_archs_and_cells_accounted():
    assert len(ARCH_IDS) == 10
    assert len(SHAPES) == 4
    full = cells(include_skipped=True)
    assert len(full) == 40
    live = cells(include_skipped=False)
    assert len(live) == 32  # 8 long_500k skips for full-attention archs
    ok, why = cell_status("mamba2_780m", "long_500k")
    assert ok
    ok, why = cell_status("granite_8b", "long_500k")
    assert not ok and "sub-quadratic" in why


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_fields_match_assignment(arch):
    cfg = get_config(arch)
    expect = {
        "mamba2_780m": (48, 1536, 50280), "zamba2_1_2b": (38, 2048, 32000),
        "granite_moe_1b_a400m": (24, 1024, 49155),
        "deepseek_moe_16b": (28, 2048, 102400),
        "olmo_1b": (16, 2048, 50304), "phi3_mini_3_8b": (32, 3072, 32064),
        "stablelm_3b": (32, 2560, 50304), "granite_8b": (36, 4096, 49152),
        "whisper_base": (6, 512, 51865),
        "llava_next_34b": (60, 7168, 64000),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.vocab) == expect
    smoke = get_config(arch, smoke=True)
    assert smoke.family == cfg.family
    assert smoke.d_model < cfg.d_model


def test_dashed_aliases():
    assert get_config("mamba2-780m").arch_id == "mamba2_780m"
