"""HTTP transport tests: one wire schema, determinism over the socket,
and the 400/404/429 error surface.

Each test binds a real ``MappingHTTPServer`` on an ephemeral loopback
port and drives it with ``urllib`` — the same stack the CI smoke leg
and ``bench_serve``'s HTTP phases use — over the restricted space of
``test_serve_service.py`` so everything stays in the fast core loop.
"""
import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.serve import MappingHTTPServer, MappingResponse

from test_serve_service import make_service, tiny_request


def _post(url, body, timeout=60.0):
    data = body if isinstance(body, bytes) else json.dumps(body).encode()
    r = urllib.request.Request(
        url + "/v1/mapping", data=data,
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(r, timeout=timeout) as resp:
        return resp.status, json.loads(resp.read())


def _get(url, path, timeout=10.0):
    with urllib.request.urlopen(url + path, timeout=timeout) as resp:
        return resp.status, resp.read()


@pytest.fixture()
def server():
    srv = MappingHTTPServer(make_service(), port=0).start()
    yield srv
    srv.close()


def test_post_mapping_roundtrip(server):
    req = tiny_request()
    code, body = _post(server.url, req.to_dict())
    assert code == 200
    resp = MappingResponse.from_dict(body)
    assert resp.status == "ok"
    assert resp.request_key == req.cache_key()
    assert resp.served_from == "search"
    assert resp.evaluated > 0
    assert resp.best is not None
    # the wire response is the service's canonical serialization
    assert body == json.loads(resp.to_json())


def test_repeat_request_is_memo_with_byte_identical_frontier(server):
    req = tiny_request().to_dict()
    _, first = _post(server.url, req)
    _, second = _post(server.url, req)
    assert second["served_from"] == "memo"
    # provenance counts the work done for THIS answer: none
    assert second["evaluated"] == 0
    assert second["from_journal"] == 0
    assert second["wall_s"] == 0.0
    # the payload itself is byte-identical — THE determinism artifact
    assert second["frontier_json"].encode() \
        == first["frontier_json"].encode()
    assert second["best"] == first["best"]
    assert second["frontier_points"] == first["frontier_points"]


def test_bad_json_and_bad_fields_are_400(server):
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post(server.url, b"{not json")
    assert ei.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post(server.url, {"network": "resnet18", "objectiv": "edp"})
    assert ei.value.code == 400
    assert "objectiv" in json.loads(ei.value.read())["error"]


def test_unknown_routes_are_404(server):
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(server.url, "/v1/nope")
    assert ei.value.code == 404
    r = urllib.request.Request(      # POST to a GET-only route
        server.url + "/v1/healthz", data=b"{}",
        headers={"Content-Type": "application/json"})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(r, timeout=10.0)
    assert ei.value.code == 404


def test_healthz_and_metrics(server):
    code, body = _get(server.url, "/v1/healthz")
    assert code == 200
    health = json.loads(body)
    assert health["status"] == "ok"
    _post(server.url, tiny_request().to_dict())
    code, text = _get(server.url, "/v1/metrics")
    assert code == 200
    text = text.decode()
    # Prometheus text exposition of the serve counters
    assert "repro_serve_requests_total 1" in text
    assert "repro_serve_served_from_search_total 1" in text
    assert "# TYPE repro_serve_requests_total counter" in text


def test_shed_is_429_with_retry_after():
    gate = threading.Event()
    svc = make_service(max_pending=1)
    srv = MappingHTTPServer(svc, port=0).start()
    try:
        # hold the single worker, then fill the one admission slot, so
        # the next distinct request is shed deterministically
        svc._queue.submit("blocker", lambda: gate.wait(30))
        while svc._queue.pending() != 0:
            pass
        svc._queue.submit("filler", lambda: None)
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(srv.url, tiny_request(seed=7).to_dict())
        assert ei.value.code == 429
        assert ei.value.headers["Retry-After"] is not None
        assert svc.stats["shed"] == 1
    finally:
        gate.set()
        srv.close()
