"""HTTP transport tests: one wire schema, determinism over the socket,
and the 400/404/429 error surface.

Each test binds a real ``MappingHTTPServer`` on an ephemeral loopback
port and drives it with ``urllib`` — the same stack the CI smoke leg
and ``bench_serve``'s HTTP phases use — over the restricted space of
``test_serve_service.py`` so everything stays in the fast core loop.
"""
import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.serve import MappingHTTPServer, MappingResponse

from test_serve_service import make_service, tiny_request


def _post(url, body, timeout=60.0):
    data = body if isinstance(body, bytes) else json.dumps(body).encode()
    r = urllib.request.Request(
        url + "/v1/mapping", data=data,
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(r, timeout=timeout) as resp:
        return resp.status, json.loads(resp.read())


def _get(url, path, timeout=10.0):
    with urllib.request.urlopen(url + path, timeout=timeout) as resp:
        return resp.status, resp.read()


@pytest.fixture()
def server():
    srv = MappingHTTPServer(make_service(), port=0).start()
    yield srv
    srv.close()


def test_post_mapping_roundtrip(server):
    req = tiny_request()
    code, body = _post(server.url, req.to_dict())
    assert code == 200
    resp = MappingResponse.from_dict(body)
    assert resp.status == "ok"
    assert resp.request_key == req.cache_key()
    assert resp.served_from == "search"
    assert resp.evaluated > 0
    assert resp.best is not None
    # the wire response is the service's canonical serialization
    assert body == json.loads(resp.to_json())


def test_repeat_request_is_memo_with_byte_identical_frontier(server):
    req = tiny_request().to_dict()
    _, first = _post(server.url, req)
    _, second = _post(server.url, req)
    assert second["served_from"] == "memo"
    # provenance counts the work done for THIS answer: none
    assert second["evaluated"] == 0
    assert second["from_journal"] == 0
    assert second["wall_s"] == 0.0
    # the payload itself is byte-identical — THE determinism artifact
    assert second["frontier_json"].encode() \
        == first["frontier_json"].encode()
    assert second["best"] == first["best"]
    assert second["frontier_points"] == first["frontier_points"]


def test_bad_json_and_bad_fields_are_400(server):
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post(server.url, b"{not json")
    assert ei.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post(server.url, {"network": "resnet18", "objectiv": "edp"})
    assert ei.value.code == 400
    assert "objectiv" in json.loads(ei.value.read())["error"]


def test_unknown_routes_are_404(server):
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(server.url, "/v1/nope")
    assert ei.value.code == 404
    r = urllib.request.Request(      # POST to a GET-only route
        server.url + "/v1/healthz", data=b"{}",
        headers={"Content-Type": "application/json"})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(r, timeout=10.0)
    assert ei.value.code == 404


def test_healthz_and_metrics(server):
    code, body = _get(server.url, "/v1/healthz")
    assert code == 200
    health = json.loads(body)
    assert health["status"] == "ok"
    _post(server.url, tiny_request().to_dict())
    code, text = _get(server.url, "/v1/metrics")
    assert code == 200
    text = text.decode()
    # Prometheus text exposition of the serve counters
    assert "repro_serve_requests_total 1" in text
    assert "repro_serve_served_from_search_total 1" in text
    assert "# TYPE repro_serve_requests_total counter" in text


def test_debug_requests_listing_and_lookup(server):
    """GET /v1/debug/requests mirrors the flight recorder: listing,
    ?limit/?slow filters, and the per-key prefix lookup; the listed
    stage timings satisfy the stage identity."""
    req = tiny_request()
    _post(server.url, req.to_dict())
    _post(server.url, req.to_dict())          # memo replay
    code, body = _get(server.url, "/v1/debug/requests")
    assert code == 200
    d = json.loads(body)
    assert d["count"] == 2
    newest, oldest = d["requests"]
    assert newest["served_from"] == "memo"    # newest first
    assert oldest["served_from"] == "search"
    assert oldest["admit_wait_s"] + oldest["evaluate_s"] \
        + oldest["respond_s"] == pytest.approx(oldest["total_s"])
    # stage sum vs the scraped latency histogram (the acceptance bar:
    # equal up to the respond-stage epsilon); the memo hit contributes
    # only its sub-ms replay
    _, text = _get(server.url, "/v1/metrics")
    line = [ln for ln in text.decode().splitlines()
            if ln.startswith("repro_serve_request_seconds_sum")][0]
    observed = float(line.split()[-1])
    stage_sum = sum(r["admit_wait_s"] + r["evaluate_s"]
                    for r in d["requests"])
    eps = sum(r["respond_s"] for r in d["requests"])
    assert abs(observed - stage_sum) <= eps + 0.05 * observed + 0.005
    # limit + per-key lookup (prefix)
    code, body = _get(server.url, "/v1/debug/requests?limit=1")
    assert json.loads(body)["count"] == 1
    key = req.cache_key()
    code, body = _get(server.url, f"/v1/debug/requests/{key[:10]}")
    assert code == 200
    assert json.loads(body)["key"] == key
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(server.url, "/v1/debug/requests/ffffffffffffffff")
    assert ei.value.code == 404
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(server.url, "/v1/debug/requests?limit=zz")
    assert ei.value.code == 400


def test_debug_requests_slow_ring_over_http():
    svc = make_service(slow_threshold_s=0.0)   # everything is "slow"
    srv = MappingHTTPServer(svc, port=0).start()
    try:
        _post(srv.url, tiny_request().to_dict())
        code, body = _get(srv.url, "/v1/debug/requests?slow=1")
        assert code == 200
        d = json.loads(body)
        assert d["count"] == 1
        full = d["requests"][0]
        assert full["slow"] and full["request"]["network"] == "resnet18"
        assert "engine_delta" in full
    finally:
        srv.close()


def test_debug_requests_404_when_disabled():
    svc = make_service(flight_cap=0)
    srv = MappingHTTPServer(svc, port=0).start()
    try:
        for path in ("/v1/debug/requests", "/v1/debug/requests/abc"):
            with pytest.raises(urllib.error.HTTPError) as ei:
                _get(srv.url, path)
            assert ei.value.code == 404
            assert "disabled" in json.loads(ei.value.read())["error"]
    finally:
        srv.close()


def test_metrics_scrape_includes_window_gauges():
    svc = make_service(slo_target_s=0.001)
    srv = MappingHTTPServer(svc, port=0).start()
    try:
        _post(srv.url, tiny_request().to_dict())
        _, text = _get(srv.url, "/v1/metrics")
        text = text.decode()
        assert "repro_serve_request_seconds_window_p50" in text
        assert "repro_serve_request_seconds_window_p99" in text
        assert "repro_serve_slo_burn_rate" in text
        assert "repro_serve_slo_breach_total 1" in text
    finally:
        srv.close()


def test_shed_is_429_with_retry_after():
    gate = threading.Event()
    svc = make_service(max_pending=1)
    srv = MappingHTTPServer(svc, port=0).start()
    try:
        # hold the single worker, then fill the one admission slot, so
        # the next distinct request is shed deterministically
        svc._queue.submit("blocker", lambda: gate.wait(30))
        while svc._queue.pending() != 0:
            pass
        svc._queue.submit("filler", lambda: None)
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(srv.url, tiny_request(seed=7).to_dict())
        assert ei.value.code == 429
        assert ei.value.headers["Retry-After"] is not None
        assert svc.stats["shed"] == 1
    finally:
        gate.set()
        srv.close()
