"""Batched engine vs per-candidate reference: differential oracle tests.

The engine's contract (DESIGN.md Section 6) is bit-identical results —
same ready/step matrices, same candidate scores, same chosen mappings,
same ``total_ns`` — for every mode and strategy. These tests enforce it
against the pre-engine path kept in ``core.search`` / ``core.overlap``.
"""
import dataclasses
import random

import numpy as np
import pytest

from repro.core import (Edge, IdentityMap, LayerSpec, SearchConfig,
                        chain_edges, describe, dram_pim, evaluate_chain,
                        max_step_in_rect, optimize_network, random_mapping,
                        ready_steps_analytical)
from repro.core.engine import (OverlapEngine, max_step_in_rect_dedup,
                               optimize_network_engine)
from repro.core.search import (_consumers_of, _optimize_network_reference,
                               _score_backward, _score_forward, candidates)
from repro.core.transform import transform_schedule


def small_arch(cols=64):
    return dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=cols)


def conv_chain():
    return [
        LayerSpec("l0", K=8, C=4, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l1", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l2", K=16, C=8, P=4, Q=4, R=3, S=3, stride=2, pad=1),
    ]


def bert_desc():
    return describe("bert_encoder", seq=16, d_model=8, heads=2, d_ff=16)


def cfg(**kw):
    base = dict(n_candidates=10, seed=0, max_steps=512)
    base.update(kw)
    return SearchConfig(**base)


# ---------------------------------------------------------------------------
# Ready-step analysis: engine (dedup / separable / batched) vs reference.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_engine_ready_steps_identity_bit_identical(seed):
    """Separable IdentityMap fast path == ready_steps_analytical, across
    strides, pads and pooling factors."""
    rng = random.Random(seed)
    P = rng.choice([4, 6, 8])
    K1 = rng.choice([2, 4])
    R = rng.choice([1, 3])
    st = rng.choice([1, 2])
    pool = rng.choice([1, 2])
    arch = small_arch(4)
    lp = LayerSpec("p", K=K1, C=2, P=P * st * pool, Q=P * st * pool,
                   R=R, S=R, pad=R // 2)
    lc = LayerSpec("c", K=2, C=K1, P=P, Q=P, R=R, S=R, stride=st,
                   pad=R // 2)
    mp = random_mapping(lp, arch, rng, 256)
    mc = random_mapping(lc, arch, rng, 256)
    cm = IdentityMap(pool=pool)
    sa, ra = ready_steps_analytical(mp, mc, cm)
    se, re = OverlapEngine().ready_steps(mp, mc, cm)
    assert np.array_equal(ra, re)
    assert np.array_equal(sa, se)


@pytest.mark.parametrize("seed", range(4))
def test_engine_ready_steps_bert_edges_bit_identical(seed):
    """Engine ready steps == reference on every BERT edge kind (HeadFold,
    HeadUnfold, both WeightMaps, Identity)."""
    desc = bert_desc()
    arch = small_arch(8)
    rng = random.Random(seed)
    maps = [random_mapping(l, arch, rng, 128) for l in desc.layers]
    eng = OverlapEngine()
    for i, edges in enumerate(desc.edges):
        for e in edges:
            sa, ra = ready_steps_analytical(maps[e.producer], maps[i],
                                            e.cmap)
            se, re = eng.ready_steps(maps[e.producer], maps[i], e.cmap)
            assert np.array_equal(ra, re), (i, e.producer)
            assert np.array_equal(sa, se), (i, e.producer)


def test_engine_ready_steps_batch_matches_single():
    """Batched (stacked) ready steps == per-candidate, over a candidate
    pool, for identity and non-identity maps."""
    desc = bert_desc()
    arch = small_arch(8)
    c = cfg()
    eng = OverlapEngine()
    rng = random.Random(3)
    prod = random_mapping(desc.layers[0], arch, rng, 128)
    for i in (3, 5):  # qk (HeadFold edge from q), out_proj (HeadUnfold)
        pool = candidates(desc.layers[i], arch, c, salt=i)
        for e in desc.edges[i]:
            if e.producer != 0:
                continue
            got = eng.ready_steps_batch(prod, pool, e.cmap)
            for m, (se, re) in zip(pool, got):
                sa, ra = ready_steps_analytical(prod, m, e.cmap)
                assert np.array_equal(sa, se)
                assert np.array_equal(ra, re)


@pytest.mark.parametrize("seed", range(4))
def test_max_step_in_rect_dedup_matches(seed):
    """Interval-dedup digit scan == reference scan on random rectangles."""
    rng = random.Random(seed)
    arch = small_arch(4)
    lp = LayerSpec("p", K=4, C=2, P=8, Q=8, R=3, S=3, pad=1)
    mp = random_mapping(lp, arch, rng, 256)
    nrng = np.random.RandomState(seed)
    shape = (3, 17)
    plo, phi = {}, {}
    for d in ("K", "P", "Q"):
        dim = lp.dim(d)
        lo = nrng.randint(0, dim, size=shape)
        ext = nrng.randint(1, dim + 1, size=shape)
        plo[d] = lo
        phi[d] = np.minimum(lo + ext, dim)
    assert np.array_equal(max_step_in_rect(mp, plo, phi),
                          max_step_in_rect_dedup(mp, plo, phi))


# ---------------------------------------------------------------------------
# Candidate scoring: engine == reference, forward and backward.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["original", "overlap", "transform"])
def test_score_forward_batch_matches_reference(mode):
    net = conv_chain()
    edges = chain_edges(net)
    arch = small_arch()
    c = cfg(mode=mode)
    ref = _optimize_network_reference(net, edges, arch, c)
    done = {i: lr for i, lr in enumerate(ref.layers)}
    eng = OverlapEngine()
    for i in range(len(net)):
        pool = candidates(net[i], arch, c, salt=i)
        has_cons = bool(_consumers_of(edges, i))
        s_ref = np.array([_score_forward(i, m, edges, done, mode, has_cons)
                          for m in pool])
        s_eng = eng.score_forward_batch(i, pool, edges, done, mode,
                                        has_cons)
        assert np.array_equal(s_ref, s_eng), i


@pytest.mark.parametrize("mode", ["overlap", "transform"])
def test_score_backward_matches_reference(mode):
    net = conv_chain()
    edges = chain_edges(net)
    arch = small_arch()
    c = cfg(mode=mode)
    fixed = {2: candidates(net[2], arch, c, salt=2)[0]}
    eng = OverlapEngine()
    for m in candidates(net[1], arch, c, salt=1):
        assert eng.score_backward(1, m, edges, fixed, mode) \
            == _score_backward(1, m, edges, fixed, mode)


# ---------------------------------------------------------------------------
# Chain evaluation: incremental == full, engine == reference.
# ---------------------------------------------------------------------------

def test_incremental_chain_eval_matches_full():
    desc = bert_desc()
    arch = small_arch()
    c = cfg()
    rng = random.Random(11)
    base_maps = [random_mapping(l, arch, rng, 128) for l in desc.layers]
    eng = OverlapEngine()
    for mode in ("original", "overlap", "transform"):
        base = eng.evaluate_chain(base_maps, desc.edges, mode)
        ref_base = evaluate_chain(base_maps, desc.edges, mode)
        assert base.total_ns == ref_base.total_ns
        for trial_at in range(len(base_maps)):
            trial = list(base_maps)
            trial[trial_at] = random_mapping(desc.layers[trial_at], arch,
                                             rng, 128)
            inc = eng.evaluate_chain(trial, desc.edges, mode,
                                     reuse=(base.layers, base_maps))
            full = evaluate_chain(trial, desc.edges, mode)
            assert inc.total_ns == full.total_ns, (mode, trial_at)
            assert inc.per_layer_ns == pytest.approx(full.per_layer_ns,
                                                     abs=0)


def test_transform_schedule_precomputed_order():
    """transform_schedule(order=...) == transform_schedule() when the order
    equals the stable argsort of the ready times."""
    rng = np.random.RandomState(5)
    ready = rng.choice([0.0, 10.0, 25.0, 70.0], size=(4, 33))
    order = np.argsort(ready.reshape(-1), kind="stable")
    a = transform_schedule(ready, 7.0, 2.5)
    b = transform_schedule(ready, 7.0, 2.5, order=order)
    assert a.end_ns == b.end_ns
    assert np.array_equal(a.finish_ns, b.finish_ns)
    assert a.moved_frac == b.moved_frac


# ---------------------------------------------------------------------------
# Whole-search differential: acceptance criterion — all four strategies on
# vgg16 and bert_encoder, engine == reference (same mappings, same total).
# ---------------------------------------------------------------------------

def _assert_search_equal(layers, edges, arch, c):
    a = optimize_network_engine(layers, edges, arch, c)
    b = _optimize_network_reference(layers, edges, arch, c)
    assert a.total_ns == b.total_ns
    assert a.per_layer_ns == pytest.approx(b.per_layer_ns, abs=0)
    for x, y in zip(a.layers, b.layers):
        assert x.mapping.blocks == y.mapping.blocks


@pytest.mark.slow
@pytest.mark.parametrize("strategy",
                         ["forward", "backward", "middle_output",
                          "middle_overall"])
def test_search_differential_vgg16(strategy):
    desc = describe("vgg16")
    arch = dram_pim(channels_per_layer=2)
    _assert_search_equal(desc.layers, desc.edges, arch,
                         cfg(n_candidates=4, max_steps=1024,
                             mode="transform", strategy=strategy))


@pytest.mark.parametrize("strategy",
                         ["forward", "backward", "middle_output",
                          "middle_overall"])
@pytest.mark.parametrize("mode", ["original", "overlap", "transform"])
def test_search_differential_bert(strategy, mode):
    desc = bert_desc()
    _assert_search_equal(desc.layers, desc.edges, small_arch(),
                         cfg(mode=mode, strategy=strategy))


@pytest.mark.parametrize("strategy", ["forward", "middle_output"])
def test_search_differential_with_refinement(strategy):
    """Refine trials reuse committed prefixes — totals must still match the
    reference's full re-evaluation exactly."""
    net = conv_chain()
    _assert_search_equal(net, chain_edges(net), small_arch(),
                         cfg(mode="transform", strategy=strategy,
                             refine_passes=2))


def test_engine_reuse_across_archs_keyed_bundles():
    """A reused engine must not serve cached analysis from a previous
    arch: mapping content keys are arch-agnostic, so caches are bundled
    per ``ArchSpec.to_key()`` (regression test for a cache-staleness bug,
    now also the DSE multi-arch reuse contract)."""
    net = conv_chain()
    edges = chain_edges(net)
    arch_a = small_arch(64)
    arch_b = dataclasses.replace(arch_a, word_bits=8)
    eng = OverlapEngine()
    for arch in (arch_a, arch_b, arch_a):
        c = cfg(mode="transform")
        got = optimize_network_engine(net, edges, arch, c, engine=eng)
        ref = _optimize_network_reference(net, edges, arch, c)
        assert got.total_ns == ref.total_ns
        # backward scoring path too (shares the score/ready caches)
        fixed = {2: candidates(net[2], arch, c, salt=2)[0]}
        m = candidates(net[1], arch, c, salt=1)[0]
        assert eng.score_backward(1, m, edges, fixed, "transform") \
            == _score_backward(1, m, edges, fixed, "transform")
    # two distinct archs -> two bundles, revisits resume the existing one
    assert eng.n_arch_bundles == 2


def test_engine_evict_arch():
    """Evicting a bundle frees it without breaking later searches; a
    fresh search under the evicted arch rebuilds from scratch and still
    matches the reference."""
    net = conv_chain()
    edges = chain_edges(net)
    arch_a = small_arch(64)
    arch_b = dataclasses.replace(arch_a, word_bits=8)
    eng = OverlapEngine()
    c = cfg(mode="transform")
    optimize_network_engine(net, edges, arch_a, c, engine=eng)
    optimize_network_engine(net, edges, arch_b, c, engine=eng)
    assert eng.n_arch_bundles == 2
    assert eng.evict_arch(arch_b)          # current bundle: resets cleanly
    assert not eng.evict_arch(arch_b)      # already gone
    assert eng.evict_arch(arch_a.to_key()) # by key string
    assert eng.n_arch_bundles == 0
    got = optimize_network_engine(net, edges, arch_b, c, engine=eng)
    ref = _optimize_network_reference(net, edges, arch_b, c)
    assert got.total_ns == ref.total_ns


def test_evict_arch_does_not_clobber_other_bundles():
    """Evicting the current arch must not make the next arch switch
    overwrite a different arch's warm bundle (regression: the post-evict
    state once registered its fresh bundle under the revisited key)."""
    net = conv_chain()
    edges = chain_edges(net)
    arch_a = small_arch(64)
    arch_b = dataclasses.replace(arch_a, word_bits=8)
    eng = OverlapEngine()
    c = cfg(mode="transform")
    optimize_network_engine(net, edges, arch_a, c, engine=eng)
    optimize_network_engine(net, edges, arch_b, c, engine=eng)
    bundle_b = eng._bundles[arch_b.to_key()]
    n_ready_b = len(bundle_b.ready)
    assert n_ready_b > 0
    optimize_network_engine(net, edges, arch_a, c, engine=eng)
    eng.evict_arch(arch_a)
    got = optimize_network_engine(net, edges, arch_b, c, engine=eng)
    assert eng._bundles[arch_b.to_key()] is bundle_b
    assert len(bundle_b.ready) == n_ready_b  # warm, not recomputed
    ref = _optimize_network_reference(net, edges, arch_b, c)
    assert got.total_ns == ref.total_ns


def test_engine_multi_arch_bundle_retention():
    """Returning to a previously seen architecture — via a content-equal
    but distinct ``ArchSpec`` object — must resume its cache bundle: the
    memoized ready-step analysis is served, not recomputed."""
    net = conv_chain()
    edges = chain_edges(net)
    arch_a = small_arch(64)
    arch_b = dataclasses.replace(arch_a, word_bits=8)
    eng = OverlapEngine()
    c = cfg(mode="transform")
    optimize_network_engine(net, edges, arch_a, c, engine=eng)
    ready_a = eng._bundles[arch_a.to_key()].ready
    n_ready = len(ready_a)
    assert n_ready > 0
    optimize_network_engine(net, edges, arch_b, c, engine=eng)
    # rebuilt spec, equal content: same bundle object, no new ready entries
    arch_a2 = type(arch_a).from_dict(arch_a.to_dict())
    assert arch_a2 is not arch_a
    res = optimize_network_engine(net, edges, arch_a2, c, engine=eng)
    assert eng._bundles[arch_a2.to_key()].ready is ready_a
    assert len(ready_a) == n_ready
    ref = _optimize_network_reference(net, edges, arch_a, c)
    assert res.total_ns == ref.total_ns


def test_use_engine_flag_dispatch():
    """optimize_network(use_engine=True) is the default and matches the
    reference path."""
    net = conv_chain()
    edges = chain_edges(net)
    arch = small_arch()
    a = optimize_network(net, edges, arch, cfg(mode="transform"))
    b = optimize_network(net, edges, arch,
                         cfg(mode="transform", use_engine=False))
    assert SearchConfig().use_engine is True
    assert a.total_ns == b.total_ns
