"""Telemetry subsystem tests (repro.obs) and its determinism contract.

Three layers:

* **Unit** — registry counters/gauges/histograms, snapshot merging,
  quantile interpolation, Prometheus exposition, the JSONL trace sink,
  counter-based span sampling, and the report renderer.
* **Hot-path guard** — the engine's sustained scoring loop must make
  *zero* dispatches into ``repro.obs`` (stats are plain dict ints,
  published as deltas at search end), so telemetry can never tax the
  inner loop; a loose wall-clock ratio backs the structural check.
* **Determinism** — DESIGN.md Section 12: telemetry observes, never
  steers. The engine must match the pre-engine reference, and a
  distributed sweep its serial twin, *byte-identically* with tracing
  enabled (including sampled), and a sweep's canonical frontier JSON
  must not change when telemetry is toggled.
"""
import json

import pytest

from repro import obs
from repro.core import (LayerSpec, SearchConfig, chain_edges, dram_pim,
                        optimize_network)
from repro.core.engine import OverlapEngine
from repro.core.search import _consumers_of, candidates
from repro.dse import (DSEConfig, DistribConfig, ParamSpace,
                       run_distributed, run_dse)
from repro.obs import (Registry, TraceSink, merge_snapshots, quantile,
                       render_prometheus, render_report)
from repro.obs.metrics import DEFAULT_BOUNDS
from repro.obs.trace import _NOOP_SPAN


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends with telemetry disabled — the
    process-global switch must never leak across tests."""
    obs.disable()
    yield
    obs.disable()


@pytest.fixture
def tiny_net(monkeypatch):
    """Patch the network lookup everywhere evaluations happen (same
    scheme as tests/test_dse_distrib.py)."""
    import repro.dse.explore as ex

    layers = [
        LayerSpec("l0", K=8, C=4, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l1", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1),
    ]
    desc = type("D", (), {"layers": layers,
                          "edges": chain_edges(layers)})()
    monkeypatch.setattr(ex, "describe", lambda name: desc)


def tiny_space() -> ParamSpace:
    return ParamSpace(
        family="dram_pim",
        axes={
            "channels_per_layer": (1, 2),
            "banks_per_channel": (2, 4),
            "columns_per_bank": (64, 128),
        },
        defaults={"channels_per_layer": 2, "banks_per_channel": 2,
                  "columns_per_bank": 64},
    )


def tiny_dcfg(**kw) -> DSEConfig:
    base = dict(network="tiny", mode="transform", budget=6,
                n_candidates=3, max_steps=256, seed=0, explorer="evolve",
                population=3)
    base.update(kw)
    return DSEConfig(**base)


# ---------------------------------------------------------------------------
# Registry / metrics units.
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = Registry()
    reg.counter("a").inc()
    reg.counter("a").inc(2.5)
    reg.gauge("g").set(7)
    h = reg.histogram("h")
    for v in (1e-6, 1e-3, 1.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3.5
    assert snap["gauges"]["g"] == 7.0
    assert snap["histograms"]["h"]["count"] == 3
    assert snap["histograms"]["h"]["sum"] == pytest.approx(1.001001)
    assert sum(snap["histograms"]["h"]["counts"]) == 3
    # get-or-create returns the same object
    assert reg.counter("a") is reg.counter("a")
    # snapshots are JSON-safe
    json.dumps(snap)


def test_histogram_bounds_mismatch_raises():
    reg = Registry()
    reg.histogram("h", bounds=(1.0, 2.0))
    reg.histogram("h", bounds=(1.0, 2.0))   # same bounds: fine
    with pytest.raises(ValueError):
        reg.histogram("h", bounds=(1.0, 3.0))


def test_quantile_interpolation_and_edges():
    assert quantile((1.0, 2.0), [0, 0, 0], 0.5) == 0.0       # empty
    # 10 observations uniform in the (1, 2] bucket
    assert quantile((1.0, 2.0), [0, 10, 0], 0.5) == pytest.approx(1.5)
    # first bucket interpolates down to 0.0
    assert quantile((1.0, 2.0), [10, 0, 0], 0.5) == pytest.approx(0.5)
    # overflow mass reports the top bound
    assert quantile((1.0, 2.0), [0, 0, 5], 0.99) == 2.0
    # default bounds cover the microsecond..minute range
    assert DEFAULT_BOUNDS[0] <= 1e-6 and DEFAULT_BOUNDS[-1] >= 100.0


def test_merge_snapshots_counters_add_gauges_max_hists_add():
    a, b = Registry(), Registry()
    a.counter("c").inc(2)
    b.counter("c").inc(3)
    a.gauge("g").set(5)
    b.gauge("g").set(2)
    a.histogram("h").observe(0.5)
    b.histogram("h").observe(0.5)
    m = merge_snapshots([a.snapshot(), b.snapshot(), {}])
    assert m["counters"]["c"] == 5.0
    assert m["gauges"]["g"] == 5.0
    assert m["histograms"]["h"]["count"] == 2
    assert m["histograms"]["h"]["sum"] == pytest.approx(1.0)


def test_render_prometheus_shape():
    reg = Registry()
    reg.counter("dse.evaluated").inc(4)
    reg.gauge("serve.queue.depth").set(1)
    reg.histogram("h", bounds=(1.0, 2.0)).observe(1.5)
    text = render_prometheus(reg.snapshot())
    assert "# TYPE repro_dse_evaluated_total counter" in text
    assert "repro_dse_evaluated_total 4" in text
    assert "repro_serve_queue_depth 1" in text
    assert 'repro_h_bucket{le="2"} 1' in text
    assert 'repro_h_bucket{le="+Inf"} 1' in text
    assert "repro_h_count 1" in text
    assert render_prometheus({}) == ""


def test_render_prometheus_labels_and_escaping():
    """Prometheus text-exposition conformance: constant labels reach
    every series (histogram buckets merge them with ``le``), and label
    values escape backslash, double-quote and newline per the format
    spec."""
    from repro.obs import escape_label_value

    assert escape_label_value('a"b') == 'a\\"b'
    assert escape_label_value("a\\b") == "a\\\\b"
    assert escape_label_value("a\nb") == "a\\nb"
    reg = Registry()
    reg.counter("c").inc(1)
    reg.gauge("g").set(2)
    reg.histogram("h", bounds=(1.0,)).observe(0.5)
    text = render_prometheus(reg.snapshot(),
                             labels={"net": 'res"net\n', "w": "a\\b"})
    assert 'repro_c_total{net="res\\"net\\n",w="a\\\\b"} 1' in text
    assert 'repro_g{net="res\\"net\\n",w="a\\\\b"} 2' in text
    # bucket lines merge the constant labels with le=
    assert 'le="1"' in text and 'net="res\\"net\\n"' in text
    for line in text.splitlines():
        if "_bucket" in line and "+Inf" not in line:
            assert line.startswith('repro_h_bucket{')
            assert 'le="1"' in line
    # no labels: unchanged legacy shape
    plain = render_prometheus(reg.snapshot())
    assert "repro_c_total 1" in plain


def test_trace_sink_concurrent_writes_no_torn_lines(tmp_path):
    """N threads hammering one TraceSink must produce valid JSONL —
    every line parses and every event arrives exactly once."""
    import threading

    path = str(tmp_path / "t.jsonl")
    sink = TraceSink(path)
    n_threads, n_events = 8, 200

    def writer(tid):
        for i in range(n_events):
            sink.write({"ev": "event", "tid_": tid, "i": i,
                        "pad": "x" * 100})

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    sink.close()
    evs = _read_events(path)      # json.loads raises on a torn line
    assert len(evs) == n_threads * n_events
    seen = {(e["tid_"], e["i"]) for e in evs}
    assert len(seen) == n_threads * n_events


def test_render_report_sections():
    assert render_report({}) == "(no metrics recorded)\n"
    reg = Registry()
    reg.counter("engine.tail_hit").inc(3)
    reg.counter("engine.tail_miss").inc(1)
    reg.counter("dse.evaluated").inc(2)
    reg.histogram("serve.request_seconds").observe(0.25)
    reg.counter("serve.requests").inc(1)
    text = render_report(reg.snapshot())
    assert "hit rate" in text and "75.0%" in text
    assert "dse" in text and "serve" in text


# ---------------------------------------------------------------------------
# Tracing: JSONL sink, nesting, sampling, global switch.
# ---------------------------------------------------------------------------

def _read_events(path):
    with open(path, "r", encoding="utf-8") as fh:
        return [json.loads(line) for line in fh]


def test_span_jsonl_nesting_and_events(tmp_path):
    trace = str(tmp_path / "trace.jsonl")
    obs.enable(trace_path=trace)
    with obs.span("outer", a=1):
        with obs.span("inner"):
            pass
        obs.event("mark", x="y")
    obs.disable()
    evs = _read_events(trace)
    by_name = {e["name"]: e for e in evs}
    assert by_name["inner"]["depth"] == 1
    assert by_name["outer"]["depth"] == 0
    assert by_name["outer"]["a"] == 1
    assert by_name["outer"]["dur_s"] >= by_name["inner"]["dur_s"] >= 0
    assert by_name["mark"]["ev"] == "event" and by_name["mark"]["x"] == "y"
    # spans also feed span.<name> duration histograms
    snap = obs.current().registry if obs.enabled() else None
    assert snap is None                       # disabled again


def test_span_sampling_is_counter_based(tmp_path):
    trace = str(tmp_path / "trace.jsonl")
    obs.enable(trace_path=trace, sample_every=3)
    for _ in range(7):
        with obs.span("s"):
            pass
    obs.disable()
    evs = _read_events(trace)
    assert len(evs) == 3      # spans 0, 3 and 6 of 7 survive the stride
    # metrics are never sampled: only emitted spans hit the histogram,
    # but plain counters always count
    obs.enable()
    for _ in range(7):
        obs.inc("c")
    assert obs.registry().snapshot()["counters"]["c"] == 7.0


def test_disabled_is_total_noop(tmp_path):
    assert not obs.enabled()
    assert obs.registry() is None
    obs.inc("x")
    obs.observe("y", 1.0)
    obs.set_gauge("z", 1.0)
    obs.event("e")
    with obs.span("s", k=1):
        pass                   # shared no-op span, nothing written
    assert obs.registry() is None


def test_metrics_without_sink():
    obs.enable()               # registry only
    assert obs.enabled() and obs.registry() is not None
    with obs.span("s"):        # no sink: no-op span, no histogram
        pass
    obs.inc("c", 2)
    snap = obs.registry().snapshot()
    assert snap["counters"]["c"] == 2.0
    assert "span.s" not in snap["histograms"]


def test_trace_sink_reopens_after_close(tmp_path):
    path = str(tmp_path / "t.jsonl")
    sink = TraceSink(path)
    sink.write({"a": 1})
    sink.close()
    sink.write({"b": 2})
    sink.close()
    assert len(_read_events(path)) == 2


# ---------------------------------------------------------------------------
# Flight recorder: bounded rings, slow-request retention, lookup.
# ---------------------------------------------------------------------------

def test_flight_recorder_ring_bounds_and_slow_retention():
    from repro.obs import FlightRecorder

    fr = FlightRecorder(cap=3, slow_threshold_s=0.5, slow_cap=2)
    assert fr.enabled and len(fr) == 0
    for i in range(5):
        fr.record({"key": f"k{i}", "total_s": 0.1})
    assert len(fr) == 3                        # ring evicted the oldest
    snap = fr.snapshot()
    assert [r["key"] for r in snap] == ["k4", "k3", "k2"]  # newest first
    assert all(not r["slow"] for r in snap)
    assert snap[0]["seq"] == 5                 # monotone sequence
    # slow records keep full detail in the separate ring
    fr.record({"key": "slow1", "total_s": 0.9},
              detail={"request": {"network": "resnet18"}})
    assert fr.snapshot()[0]["slow"]
    slow = fr.snapshot(slow_only=True)
    assert len(slow) == 1
    assert slow[0]["request"] == {"network": "resnet18"}
    # ...and survive main-ring rotation
    for i in range(10):
        fr.record({"key": f"x{i}", "total_s": 0.0})
    assert fr.get("slow1")["request"] == {"network": "resnet18"}
    # prefix match; unknown and empty keys are None
    assert fr.get("slo")["key"] == "slow1"
    assert fr.get("nope") is None and fr.get("") is None
    # snapshot limit
    assert len(fr.snapshot(limit=2)) == 2
    json.dumps(fr.snapshot())


def test_flight_recorder_cap_zero_is_noop():
    from repro.obs import FlightRecorder

    fr = FlightRecorder(cap=0)
    assert not fr.enabled
    fr.record({"key": "k", "total_s": 99.0})
    assert len(fr) == 0 and fr.snapshot() == [] and fr.get("k") is None


# ---------------------------------------------------------------------------
# Sliding windows: recent quantiles, aging, SLO burn rate.
# ---------------------------------------------------------------------------

class _FakeClock:
    """Deterministic monotonic clock for window tests."""

    def __init__(self, t=1000.0):
        self.t = t

    def __call__(self):
        return self.t


def test_window_histogram_quantiles_and_aging():
    from repro.obs import WindowHistogram

    clk = _FakeClock()
    w = WindowHistogram(window_s=60.0, n_slots=12, clock=clk)
    assert w.count() == 0 and w.quantile(0.5) == 0.0
    for v in (0.010, 0.011, 0.012, 0.013):
        w.observe(v)
    assert w.count() == 4
    assert w.quantile(0.5) == pytest.approx(0.012, rel=0.5)
    assert w.mean() == pytest.approx(0.0115)
    # half a window later the old slots are still live...
    clk.t += 30.0
    w.observe(0.5)
    assert w.count() == 5
    # ...a full window after the first batch, only the new one remains
    clk.t += 31.0
    assert w.count() == 1
    assert w.quantile(0.99) == pytest.approx(0.5, rel=0.5)
    # and past that, the window is empty again
    clk.t += 61.0
    assert w.count() == 0 and w.quantile(0.5) == 0.0
    snap = w.snapshot()
    assert snap["count"] == 0 and sum(snap["counts"]) == 0
    json.dumps(snap)


def test_slo_tracker_burn_rate():
    from repro.obs import SLOTracker

    clk = _FakeClock()
    slo = SLOTracker(target_s=0.1, goal=0.9, window_s=60.0, clock=clk)
    assert slo.burn_rate() == 0.0               # empty window
    for _ in range(9):
        slo.observe(0.05)                       # ok
    slo.observe(0.5)                            # breach
    assert slo.n_ok == 9 and slo.n_breach == 1
    # 10% breaches against a 10% error budget: burning exactly at 1.0
    assert slo.window_breach_rate() == pytest.approx(0.1)
    assert slo.burn_rate() == pytest.approx(1.0)
    snap = slo.snapshot()
    assert snap["ok"] == 9 and snap["breach"] == 1
    json.dumps(snap)
    # the windowed rate ages out; the all-time counters do not
    clk.t += 120.0
    assert slo.burn_rate() == 0.0
    assert slo.n_breach == 1


# ---------------------------------------------------------------------------
# Engine publication: delta semantics, zero hot-path dispatch.
# ---------------------------------------------------------------------------

def _small_arch():
    return dram_pim(channels_per_layer=2, banks_per_channel=2,
                    columns_per_bank=64)


def _conv_chain():
    return [
        LayerSpec("l0", K=8, C=4, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l1", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l2", K=16, C=8, P=4, Q=4, R=3, S=3, stride=2, pad=1),
    ]


def _sustained_setup(n_candidates=8):
    layers = _conv_chain()
    edges = chain_edges(layers)
    arch = _small_arch()
    cfg = SearchConfig(n_candidates=n_candidates, seed=0, max_steps=512,
                       mode="transform")
    res = optimize_network(layers, edges, arch, cfg)
    done = {i: lr for i, lr in enumerate(res.layers)}
    scored = [(i, candidates(layers[i], arch, cfg, salt=i),
               bool(_consumers_of(edges, i)))
              for i in range(len(layers)) if edges[i]]
    return edges, done, scored


def test_publish_metrics_publishes_deltas_once():
    eng = OverlapEngine()
    edges, done, scored = _sustained_setup()
    for i, pool, has_cons in scored:
        eng.score_forward_batch(i, pool, edges, done, "transform",
                                has_cons)
    reg = Registry()
    eng.publish_metrics(registry=reg)
    first = reg.snapshot()["counters"]
    assert first.get("engine.score_miss", 0) > 0
    # publishing again without new work adds nothing (delta semantics)
    eng.publish_metrics(registry=reg)
    assert reg.snapshot()["counters"] == first
    # with telemetry disabled and no explicit registry: a silent no-op
    eng.publish_metrics()


def test_sustained_scoring_makes_zero_obs_dispatches(monkeypatch):
    """The structural half of the <5% overhead guarantee: neither the
    cold nor the memo-hit scoring pass may call into ``repro.obs`` at
    all — engine stats are plain dict ints until ``publish_metrics``."""
    eng = OverlapEngine()
    edges, done, scored = _sustained_setup()   # before patching: the
    # setup's own optimize_network legitimately opens search spans
    calls = []
    for fn in ("inc", "observe", "set_gauge", "event", "span"):
        monkeypatch.setattr(obs, fn,
                            lambda *a, _f=fn, **k: calls.append(_f)
                            or _NOOP_SPAN)
    for _ in range(2):          # cold pass, then the sustained regime
        for i, pool, has_cons in scored:
            eng.score_forward_batch(i, pool, edges, done, "transform",
                                    has_cons)
    assert calls == []
    assert eng.stats["score_pool_hit"] > 0      # the memo regime ran


def test_sustained_scoring_overhead_is_bounded():
    """Wall-clock half, deliberately loose (a gross-regression tripwire
    only — ``bench_search.obs_overhead`` tracks the real number): the
    same sustained pass with telemetry enabled must stay within 2x of
    disabled."""
    import time

    eng = OverlapEngine()
    edges, done, scored = _sustained_setup(n_candidates=16)

    def one_pass():
        t0 = time.perf_counter()
        for _ in range(20):
            for i, pool, has_cons in scored:
                eng.score_forward_batch(i, pool, edges, done,
                                        "transform", has_cons)
        return time.perf_counter() - t0

    one_pass()                  # warm the memo tables
    t_off = min(one_pass() for _ in range(3))
    obs.enable()
    t_on = min(one_pass() for _ in range(3))
    obs.disable()
    assert t_on <= 2.0 * t_off, (t_on, t_off)


# ---------------------------------------------------------------------------
# Fleet shards: worker-local registries merged by the coordinator.
# ---------------------------------------------------------------------------

def test_fleet_shard_write_and_collect(tmp_path):
    from repro.dse.distrib.coordinator import clear_metrics, collect_fleet
    from repro.dse.distrib.worker import write_metrics_shard

    root = str(tmp_path)
    assert collect_fleet(root) is None          # no shards yet
    for wid, n in (("w0", 3), ("w1", 5)):
        reg = Registry()
        reg.counter("fleet.evaluated").inc(n)
        reg.histogram("fleet.batch_eval_seconds").observe(0.1 * n)
        write_metrics_shard(root, wid, {"evaluated": n, "batches": 1},
                            reg)
    fleet = collect_fleet(root)
    assert fleet["summary"]["workers_reported"] == 2
    assert fleet["summary"]["evaluated"] == 8
    assert fleet["summary"]["batches"] == 2
    assert fleet["summary"]["batch_eval_p50_s"] > 0
    snap = fleet["snapshot"]
    assert snap["counters"]["fleet.evaluated"] == 8.0
    assert snap["gauges"]["fleet.workers"] == 2.0
    clear_metrics(root)
    assert collect_fleet(root) is None


# ---------------------------------------------------------------------------
# Determinism: telemetry observes, never steers (DESIGN.md Section 12).
# ---------------------------------------------------------------------------

def test_engine_matches_reference_with_tracing_on(tmp_path):
    layers = _conv_chain()
    edges = chain_edges(layers)
    arch = _small_arch()
    cfg = SearchConfig(n_candidates=8, seed=0, max_steps=512,
                       mode="transform", refine_passes=1)
    ref = optimize_network(layers, edges, arch,
                           SearchConfig(n_candidates=8, seed=0,
                                        max_steps=512, mode="transform",
                                        refine_passes=1,
                                        use_engine=False))
    obs.enable(trace_path=str(tmp_path / "t.jsonl"), sample_every=2)
    traced = optimize_network(layers, edges, arch, cfg)
    obs.disable()
    untraced = optimize_network(layers, edges, arch, cfg)
    assert traced.total_ns == ref.total_ns == untraced.total_ns
    assert [l.latency_ns for l in traced.layers] \
        == [l.latency_ns for l in ref.layers]


def test_sweep_frontier_identical_with_telemetry_toggled(tiny_net,
                                                         tmp_path):
    base = run_dse(tiny_dcfg(), space=tiny_space())
    obs.enable(trace_path=str(tmp_path / "t.jsonl"))
    traced = run_dse(tiny_dcfg(), space=tiny_space())
    obs.disable()
    sampled = obs.enable(sample_every=4)
    assert sampled.enabled
    resampled = run_dse(tiny_dcfg(), space=tiny_space())
    obs.disable()
    assert traced.frontier.canonical_json() \
        == base.frontier.canonical_json() \
        == resampled.frontier.canonical_json()
    # the traced run actually recorded sweep metrics
    evs = _read_events(str(tmp_path / "t.jsonl"))
    assert any(e["name"] == "dse.sweep" for e in evs)


def test_distributed_matches_serial_with_telemetry_on(tiny_net,
                                                      tmp_path):
    serial = run_dse(tiny_dcfg(), space=tiny_space())
    obs.enable(trace_path=str(tmp_path / "t.jsonl"))
    res = run_distributed(
        tiny_dcfg(), DistribConfig(root=str(tmp_path / "shared"),
                                   n_workers=2, worker_mode="thread"),
        space=tiny_space())
    snap = obs.registry().snapshot()
    obs.disable()
    assert res.frontier.canonical_json() == serial.frontier.canonical_json()
    # the workers' shard metrics were folded into the global registry
    assert snap["counters"]["fleet.evaluated"] == res.stats["evaluated"]
    assert res.stats["fleet"]["workers_reported"] == 2
    assert res.stats["fleet"]["claims"] >= res.stats["fleet"]["batches"]
