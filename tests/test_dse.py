"""DSE subsystem tests: spaces, Pareto frontier, journal resume, explorer
determinism and the end-to-end (arch x mapping) co-search.

Search-running tests use a tiny conv chain (not resnet18) so the whole
module stays in the fast core loop; the full-budget acceptance path is
exercised by the ``dse`` benchmark subcommand and CI smoke job.
"""
import json
import random

import pytest

from repro.core import (ArchSpec, LayerSpec, arch_area_proxy,
                        arch_power_proxy, chain_edges, dram_pim)
from repro.dse import (DEFAULT_OBJECTIVES, DSEConfig, DesignPoint,
                       ParamSpace, ParetoFrontier, RunJournal, dominates,
                       dram_space, get_space, reram_space, run_dse,
                       tpu_space)
from repro.dse.explore import _Evaluator, evaluate_point, point_key
from repro.dse.report import frontier_table, summarize


def tiny_space() -> ParamSpace:
    return ParamSpace(
        family="dram_pim",
        axes={
            "channels_per_layer": (1, 2),
            "banks_per_channel": (2, 4),
            "columns_per_bank": (64, 128),
        },
        constraints=[
            lambda p: p["channels_per_layer"] * p["banks_per_channel"] <= 4,
        ],
        defaults={"channels_per_layer": 2, "banks_per_channel": 2,
                  "columns_per_bank": 64},
    )


def tiny_dcfg(**kw) -> DSEConfig:
    base = dict(network="resnet18", mode="transform", budget=4,
                n_candidates=3, max_steps=256, seed=0, explorer="grid")
    base.update(kw)
    return DSEConfig(**base)


# ---------------------------------------------------------------------------
# Parameter spaces.
# ---------------------------------------------------------------------------

def test_space_enumerate_respects_constraints():
    sp = tiny_space()
    pts = list(sp.enumerate())
    # 2*2*2 = 8 grid points, minus the (2 channels x 4 banks) pairs
    assert len(pts) == 6
    for p in pts:
        d = p.as_dict()
        assert d["channels_per_layer"] * d["banks_per_channel"] <= 4
    assert len({p.key() for p in pts}) == len(pts)


def test_space_default_builds_factory_default():
    assert dram_space().build(dram_space().default()) == dram_pim()


def test_space_point_rejects_invalid():
    sp = tiny_space()
    with pytest.raises(ValueError):
        sp.point(channels_per_layer=2, banks_per_channel=4,
                 columns_per_bank=64)  # violates the fanout constraint
    with pytest.raises(ValueError):
        sp.point(channels_per_layer=3, banks_per_channel=2,
                 columns_per_bank=64)  # off-axis value


def test_space_build_applies_timing_scale_and_target():
    sp = dram_space()
    p = sp.point(timing_scale=1.25, target_level="Channel")
    arch = sp.build(p)
    base = dram_pim()
    assert arch.target_level == "Channel"
    assert arch.timing.t_rc == base.timing.t_rc * 1.25
    ops = arch.compute_level.pim_ops
    assert ops["add"] == base.compute_level.pim_ops["add"] * 1.25
    # energies are untouched: scaled bins change power, not energy
    assert arch.timing.e_act == base.timing.e_act
    assert arch_power_proxy(arch) < arch_power_proxy(base)


def test_space_build_scales_pinned_ops_for_precision():
    """word_bits=8 must not get its energy win at unchanged latency: the
    pinned 16-bit op latencies rescale (add ~n, mul ~n^2 — the Section
    IV-C bit-serial structure), or low precision would Pareto-dominate as
    a pure modeling artifact."""
    sp = dram_space()
    base_ops = dram_pim().compute_level.pim_ops
    arch8 = sp.build(sp.point(word_bits=8))
    assert arch8.compute_level.pim_ops["add"] == base_ops["add"] * 0.5
    assert arch8.compute_level.pim_ops["mul"] == base_ops["mul"] * 0.25
    assert sp.build(sp.default()).compute_level.pim_ops == base_ops


def test_space_mutate_steps_one_gene():
    sp = tiny_space()
    rng = random.Random(3)
    for _ in range(32):
        p = sp.sample(rng)
        q = sp.mutate(p, rng)
        assert q.key() != p.key()
        assert sp.is_valid(q.as_dict())
        diff = [k for k in q.as_dict()
                if q.as_dict()[k] != p.as_dict()[k]]
        assert len(diff) == 1


def test_space_crossover_mixes_parent_genes():
    sp = tiny_space()
    rng = random.Random(4)
    a = sp.point(channels_per_layer=1, banks_per_channel=2,
                 columns_per_bank=64)
    b = sp.point(channels_per_layer=2, banks_per_channel=2,
                 columns_per_bank=128)
    for _ in range(16):
        c = sp.crossover(a, b, rng).as_dict()
        for k, v in c.items():
            assert v in (a.as_dict()[k], b.as_dict()[k])
        assert sp.is_valid(c)


def test_all_shipped_spaces_build_their_points():
    for name in ("dram_pim", "reram_pim", "tpu_spatial"):
        sp = get_space(name)
        rng = random.Random(0)
        for p in [sp.default()] + [sp.sample(rng) for _ in range(5)]:
            arch = sp.build(p)
            assert isinstance(arch, ArchSpec)
            assert arch_area_proxy(arch) > 0
            assert arch_power_proxy(arch) > 0
            # points round-trip through their content keys
            assert sp.point(**p.as_dict()) == p


def test_cost_proxies_ignore_analysis_level():
    """Moving the overlap-analysis level (a DSE axis) does not change
    the physical hardware, so it must not change its area/power cost —
    otherwise Channel-target points spuriously dominate the frontier."""
    import dataclasses
    base = dram_pim()
    moved = dataclasses.replace(base, target_level="Channel")
    assert arch_area_proxy(moved) == arch_area_proxy(base)
    assert arch_power_proxy(moved) == arch_power_proxy(base)


def test_area_proxy_orders_allocations():
    """More banks/columns => more area; fewer channels => less area."""
    base = dram_pim(2, 8, 8192)
    assert arch_area_proxy(dram_pim(2, 16, 8192)) > arch_area_proxy(base)
    assert arch_area_proxy(dram_pim(2, 8, 16384)) > arch_area_proxy(base)
    assert arch_area_proxy(dram_pim(1, 16, 8192)) < arch_area_proxy(base)


# ---------------------------------------------------------------------------
# Pareto frontier.
# ---------------------------------------------------------------------------

def test_dominates_semantics():
    assert dominates((1, 1), (2, 1))
    assert not dominates((1, 1), (1, 1))
    assert not dominates((1, 2), (2, 1))


def test_frontier_incremental_pruning():
    f = ParetoFrontier(("a", "b"))
    assert f.add("p1", (2.0, 2.0))
    assert f.add("p2", (1.0, 3.0))       # tradeoff: kept
    assert not f.add("p3", (3.0, 3.0))   # dominated: rejected
    assert f.add("p4", (1.0, 1.0))       # dominates p1 and p2: evicts both
    assert len(f) == 1 and f.points[0].key == "p4"
    assert not f.add("p5", (1.0, 1.0))   # duplicate objectives: rejected
    assert f.dominated((1.5, 1.0))
    assert not f.dominated((0.5, 5.0))


def test_frontier_exact_tie_on_all_objectives_keeps_first():
    """A candidate tying an incumbent on *every* objective is redundant:
    rejected, incumbent (first writer) retained — resume idempotence."""
    f = ParetoFrontier(("a", "b"))
    assert f.add("first", (2.0, 3.0))
    assert not f.add("second", (2.0, 3.0))
    assert len(f) == 1 and f.points[0].key == "first"
    # and ints tie floats: objectives are canonicalized to float
    assert not f.add("third", (2, 3))
    assert f.dominated((2.0, 3.0))


def test_frontier_equal_latency_different_area_both_kept():
    """Points equal on one objective but trading the other are mutually
    non-dominating (dominance needs a *strict* win somewhere)."""
    f = ParetoFrontier(("total_ns", "area_mm2"))
    assert f.add("small", (10.0, 1.0))
    assert f.add("big", (10.0, 2.0)) is False  # dominated: same lat, worse area
    assert f.add("fast_big", (5.0, 2.0))       # trade: kept
    assert {p.key for p in f.points} == {"small", "fast_big"}
    # equal latency, *better* area evicts the incumbent
    assert f.add("smaller", (10.0, 0.5))
    assert {p.key for p in f.points} == {"smaller", "fast_big"}


def test_frontier_duplicate_point_insertion_idempotent():
    """Re-offering every frontier point (a resumed sweep replaying its
    journal) changes nothing: same size, same keys, same order."""
    f = ParetoFrontier(("a", "b"))
    pts = [("p1", (1.0, 4.0)), ("p2", (2.0, 2.0)), ("p3", (4.0, 1.0)),
           ("dom", (5.0, 5.0))]
    for k, o in pts:
        f.add(k, o)
    before = [(p.key, p.objectives) for p in f.points]
    canon = f.canonical_json()
    for k, o in pts:
        assert not f.add(k, o)
    assert [(p.key, p.objectives) for p in f.points] == before
    assert f.canonical_json() == canon


def test_frontier_canonical_json_order_independent():
    """The canonical serialization must not depend on insertion order —
    it is the cross-run byte-equality witness."""
    a, b = ParetoFrontier(("x", "y")), ParetoFrontier(("x", "y"))
    pts = [("p1", (1.0, 4.0)), ("p2", (2.0, 2.0)), ("p3", (4.0, 1.0))]
    for k, o in pts:
        a.add(k, o)
    for k, o in reversed(pts):
        b.add(k, o)
    assert a.canonical_json() == b.canonical_json()


def test_frontier_best_and_record_api():
    f = ParetoFrontier(DEFAULT_OBJECTIVES)
    f.add_record("x", {"total_ns": 10.0, "energy_pj": 5.0,
                       "area_mm2": 2.0})
    f.add_record("y", {"total_ns": 5.0, "energy_pj": 5.0,
                       "area_mm2": 4.0})
    assert f.best("total_ns").key == "y"
    assert f.best("area_mm2").key == "x"


# ---------------------------------------------------------------------------
# Journal persistence + resume.
# ---------------------------------------------------------------------------

def test_journal_roundtrip_and_truncation(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = RunJournal(path)
    j.record("k1", {"total_ns": 1.0})
    j.record("k2", {"total_ns": 2.0})
    with open(path, "a") as fh:
        fh.write('{"key": "k3", "total_ns"')  # killed mid-append
    j2 = RunJournal(path)
    assert len(j2) == 2 and j2.get("k1")["total_ns"] == 1.0
    assert "k3" not in j2
    # later lines win on key collisions (re-append is harmless)
    j2.record("k1", {"total_ns": 9.0})
    assert RunJournal(path).get("k1")["total_ns"] == 9.0


def test_journal_compact_drops_duplicates_and_truncation(tmp_path):
    """compact() rewrites the JSONL to one line per key: superseded
    later-wins duplicates and the truncated tail disappear, the merged
    view is unchanged, and appends keep working afterwards."""
    path = str(tmp_path / "j.jsonl")
    j = RunJournal(path)
    j.record("k1", {"total_ns": 1.0})
    j.record("k2", {"total_ns": 2.0})
    j.record("k1", {"total_ns": 9.0})   # supersedes the first k1
    with open(path, "a") as fh:
        fh.write('{"key": "k3", "total_ns"')  # killed mid-append
    before, after = RunJournal(path).compact()
    assert (before, after) == (4, 2)
    with open(path) as fh:
        lines = [l for l in fh.read().splitlines() if l.strip()]
    assert len(lines) == 2
    j2 = RunJournal(path)
    assert len(j2) == 2
    assert j2.get("k1")["total_ns"] == 9.0 and "k3" not in j2
    j2.record("k4", {"total_ns": 4.0})  # tail is clean post-compact
    assert RunJournal(path).get("k4")["total_ns"] == 4.0
    # in-memory journals have nothing to compact
    assert RunJournal().compact() == (0, 0)


def test_shared_dir_backend_publish_and_merge(tmp_path):
    """SharedDirBackend: appends are invisible until publish; published
    shards merge later-wins across writers; refresh picks up peers."""
    from repro.dse import SharedDirBackend
    root = str(tmp_path / "root")
    a = RunJournal(backend=SharedDirBackend(root, writer_id="a"))
    b = RunJournal(backend=SharedDirBackend(root, writer_id="b"))
    a.record("k1", {"total_ns": 1.0})
    assert b.refresh() == 0          # staged, not yet published
    a.publish()
    assert b.refresh() == 1
    assert b.get("k1")["total_ns"] == 1.0
    b.record("k2", {"total_ns": 2.0})
    b.publish()
    fresh = RunJournal(backend=SharedDirBackend(root, writer_id="c"))
    assert len(fresh) == 2
    # later-wins by content key across shards
    b.record("k1", {"total_ns": 7.0})
    b.publish()
    assert RunJournal(backend=SharedDirBackend(root)).get("k1")[
        "total_ns"] == 7.0


def test_shared_dir_backend_compact(tmp_path):
    """Shared-dir compaction folds every shard into one and drops
    superseded records; concurrent readers keep a complete view."""
    from repro.dse import SharedDirBackend
    root = str(tmp_path / "root")
    a = RunJournal(backend=SharedDirBackend(root, writer_id="a"))
    for i in range(3):
        a.record("k1", {"total_ns": float(i)})
        a.publish()                      # three shards, same key
    a.record("k2", {"total_ns": 5.0})
    a.publish()
    reader = RunJournal(backend=SharedDirBackend(root, writer_id="r"))
    before, after = a.compact()
    assert (before, after) == (4, 2)
    assert len(a.backend.shards()) == 1
    assert a.get("k1")["total_ns"] == 2.0
    # a pre-compact reader still refreshes to a complete view
    reader.refresh()
    assert reader.get("k1")["total_ns"] == 2.0
    assert reader.get("k2")["total_ns"] == 5.0


def test_point_key_content_identity():
    sp = tiny_space()
    d1, d2 = tiny_dcfg(), tiny_dcfg()
    p = sp.default()
    assert point_key(sp, p, d1) == point_key(sp, p, d2)
    assert point_key(sp, p, d1) != point_key(sp, p, tiny_dcfg(seed=7))
    q = sp.point(channels_per_layer=1, banks_per_channel=2,
                 columns_per_bank=64)
    assert point_key(sp, p, d1) != point_key(sp, q, d1)


def test_objective_journal_key_separation():
    """Non-latency objectives get distinct journal keys (their chosen
    mappings differ); blend keys depend on alpha; transform-mode keys
    are revved past the pre-energy derivation (their records changed:
    energy now includes relocation) while original/overlap keys still
    match it — journals from before the energy-aware search keep
    serving the modes whose records are unchanged, and only those."""
    import hashlib
    import json as _json
    sp = tiny_space()
    p = sp.default()
    lat = point_key(sp, p, tiny_dcfg())
    keys = {lat}
    for obj in ("energy", "edp", "blend"):
        keys.add(point_key(sp, p, tiny_dcfg(objective=obj)))
    assert len(keys) == 4
    # blend keys depend on alpha too
    assert point_key(sp, p, tiny_dcfg(objective="blend", blend_alpha=0.5)) \
        != point_key(sp, p, tiny_dcfg(objective="blend", blend_alpha=0.9))

    def pre_energy_key(d):
        blob = _json.dumps(
            {"network": d.network, "mode": d.mode, "strategy": d.strategy,
             "seed": d.seed, "n_candidates": d.n_candidates,
             "max_steps": d.max_steps, "refine_passes": d.refine_passes,
             "arch_key": sp.build(p).to_key()},
            sort_keys=True, separators=(",", ":"))
        return hashlib.sha1(blob.encode()).hexdigest()

    # transform: intentionally invalidated (stale energies must re-eval)
    assert lat != pre_energy_key(tiny_dcfg())
    # original/overlap: records unchanged, legacy keys preserved
    for mode in ("original", "overlap"):
        d = tiny_dcfg(mode=mode)
        assert point_key(sp, p, d) == pre_energy_key(d)


def test_dse_config_rejects_bad_objective_args():
    with pytest.raises(AssertionError):
        tiny_dcfg(objective="joules")
    with pytest.raises(AssertionError):
        tiny_dcfg(objective="blend", blend_alpha=1.5)


def test_frontier_table_tolerates_pre_energy_records():
    """Journal records written before the energy-aware search lack
    move_energy_pj; the frontier table must render them (as '-'), not
    crash a resumed sweep's report."""
    f = ParetoFrontier()
    f.add_record("old", {"total_ns": 10.0, "energy_pj": 5.0,
                         "area_mm2": 1.0, "arch_name": "a", "point": {}})
    f.add_record("new", {"total_ns": 5.0, "energy_pj": 9.0,
                         "area_mm2": 1.0, "arch_name": "b", "point": {},
                         "move_energy_pj": 123.0, "power_w": 1.0})
    out = frontier_table(f)
    assert "move_energy_J" in out and "-" in out


def test_records_carry_objective_fields(monkeypatch):
    """Fresh evaluations journal the objective and its scalarized value
    (the evolutionary fitness), plus the move-energy/EDP columns."""
    layers = [LayerSpec("l0", K=4, C=4, P=4, Q=4, R=3, S=3, pad=1),
              LayerSpec("l1", K=4, C=4, P=4, Q=4, R=3, S=3, pad=1)]
    import repro.dse.explore as ex
    monkeypatch.setattr(
        ex, "describe",
        lambda name: type("D", (), {"layers": layers,
                                    "edges": chain_edges(layers)})())
    sp = tiny_space()
    res = run_dse(tiny_dcfg(objective="edp", budget=3), space=sp)
    for rec in res.records:
        assert rec["objective"] == "edp"
        assert rec["objective_value"] == \
            rec["total_ns"] * rec["energy_pj"]
        assert rec["edp_ns_pj"] == rec["total_ns"] * rec["energy_pj"]
        assert rec["move_energy_pj"] >= 0.0
        assert rec["energy_pj"] >= rec["move_energy_pj"]
    assert res.best_by("edp_ns_pj") is not None


# ---------------------------------------------------------------------------
# Explorers: determinism, journal reuse, stub-landscape behavior.
# ---------------------------------------------------------------------------

def _patched_run(dcfg, space, journal, monkeypatch):
    """run_dse with the mapping search replaced by an analytic landscape
    (bigger allocations are strictly faster), so explorer logic is
    testable in milliseconds. Journal semantics stay real."""
    import repro.dse.explore as ex

    def fake_call(self, points):
        out = []
        for p in points:
            k = point_key(self.space, p, self.dcfg)
            hit = self.journal.get(k)
            if hit is None:
                d = p.as_dict()
                total = 1e9 / (d["channels_per_layer"]
                               * d["banks_per_channel"]
                               * d["columns_per_bank"])
                hit = self.journal.record(k, {
                    "family": p.family, "point": d,
                    "point_key": p.key(),
                    "arch_name": self.space.build(p).name,
                    "total_ns": total, "energy_pj": 1.0,
                    **self.space.costs(p)})
                self.n_evaluated += 1
            else:
                self.n_from_journal += 1
            out.append(hit)
        return out

    monkeypatch.setattr(ex._Evaluator, "__call__", fake_call)
    return ex.run_dse(dcfg, space=space, journal=journal)


@pytest.mark.parametrize("explorer", ["grid", "random", "evolve"])
def test_explorers_deterministic_and_resumable(explorer, monkeypatch):
    sp = tiny_space()
    dcfg = tiny_dcfg(explorer=explorer, budget=5, seed=3)
    j = RunJournal()
    r1 = _patched_run(dcfg, sp, j, monkeypatch)
    keys1 = [r["point"] for r in r1.records]
    assert len(r1.records) == 5
    assert r1.records[0]["point"] == sp.default().as_dict()  # baseline 1st
    assert len({json.dumps(k, sort_keys=True) for k in keys1}) == 5
    # resume on the same journal: same proposals, zero evaluations
    r2 = _patched_run(dcfg, sp, j, monkeypatch)
    assert [r["point"] for r in r2.records] == keys1
    # fresh journal, same seed: identical proposal sequence
    r3 = _patched_run(dcfg, sp, RunJournal(), monkeypatch)
    assert [r["point"] for r in r3.records] == keys1


def test_evolve_converges_on_stub_landscape(monkeypatch):
    """On a landscape where bigger allocations are strictly faster, the
    evolutionary explorer must find the fastest valid config."""
    sp = tiny_space()
    dcfg = tiny_dcfg(explorer="evolve", budget=6, seed=1, population=3)
    res = _patched_run(dcfg, sp, RunJournal(), monkeypatch)
    best = min(res.records, key=lambda r: r["total_ns"])
    # fastest valid point: 1ch x 4 banks x 128 cols or 2ch x 2 x 128
    assert best["total_ns"] == pytest.approx(1e9 / (4 * 128))


# ---------------------------------------------------------------------------
# End-to-end on a real (tiny) search.
# ---------------------------------------------------------------------------

def test_run_dse_end_to_end_tiny(tmp_path, monkeypatch):
    """Real mapping searches over a tiny space/net: the frontier is
    non-trivial, records carry real objectives, and a journal re-run
    performs zero new searches while reproducing every number."""
    layers = [
        LayerSpec("l0", K=8, C=4, P=8, Q=8, R=3, S=3, pad=1),
        LayerSpec("l1", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1),
    ]
    import repro.dse.explore as ex
    monkeypatch.setattr(
        ex, "describe",
        lambda name: type("D", (), {"layers": layers,
                                    "edges": chain_edges(layers)})())
    sp = tiny_space()
    path = str(tmp_path / "run.jsonl")
    dcfg = tiny_dcfg(explorer="grid", budget=6, journal_path=path)
    r1 = run_dse(dcfg, space=sp)
    assert r1.stats["evaluated"] == 6
    assert len(r1.frontier) >= 2
    for rec in r1.records:
        assert rec["total_ns"] > 0 and rec["energy_pj"] > 0
        assert rec["area_mm2"] > 0 and rec["power_w"] > 0
    r2 = run_dse(dcfg, space=sp)
    assert r2.stats["evaluated"] == 0
    assert r2.stats["from_journal"] == 6
    assert [r["total_ns"] for r in r2.records] == \
        [r["total_ns"] for r in r1.records]
    # report rendering smoke
    assert "frontier" in summarize(r2)
    assert "latency_ms" in frontier_table(r2.frontier)


def test_serial_evaluator_evicts_bundles(monkeypatch):
    """Each arch point is scored once per sweep, so the shared engine
    must not pin a cache bundle per point (memory stays bounded)."""
    layers = [LayerSpec("l0", K=4, C=4, P=4, Q=4, R=3, S=3, pad=1)]
    import repro.dse.explore as ex
    monkeypatch.setattr(
        ex, "describe",
        lambda name: type("D", (), {"layers": layers,
                                    "edges": chain_edges(layers)})())
    sp = tiny_space()
    ev = _Evaluator(sp, tiny_dcfg(), RunJournal())
    ev(list(sp.enumerate()))
    assert ev.n_evaluated == 6
    assert ev.engine.n_arch_bundles == 0


@pytest.mark.slow
def test_pool_matches_serial_with_custom_space():
    """workers>0 must score the caller's space — including a custom one
    whose axes differ from the shipped family space — bit-identically to
    serial mode (regression: workers once rebuilt the shipped space)."""
    sp = ParamSpace(
        family="dram_pim",
        axes={
            "channels_per_layer": (1, 2),
            "banks_per_channel": (2,),
            "columns_per_bank": (96, 160),  # off the shipped axes
        },
        defaults={"channels_per_layer": 2, "banks_per_channel": 2,
                  "columns_per_bank": 96},
    )
    dcfg = dict(network="resnet18", mode="transform", explorer="grid",
                budget=3, n_candidates=2, max_steps=128, seed=0)
    serial = run_dse(DSEConfig(**dcfg, workers=0), space=sp)
    pooled = run_dse(DSEConfig(**dcfg, workers=2), space=sp)
    assert pooled.stats["evaluated"] == serial.stats["evaluated"] == 3
    for a, b in zip(serial.records, pooled.records):
        assert a["point"] == b["point"]
        assert a["total_ns"] == b["total_ns"]
        assert a["energy_pj"] == b["energy_pj"]
        assert a["key"] == b["key"]


def test_evaluate_point_matches_direct_search(monkeypatch):
    """A DSE evaluation is exactly optimize_network on the built arch."""
    from repro.core import SearchConfig, optimize_network
    layers = [
        LayerSpec("l0", K=4, C=4, P=4, Q=4, R=3, S=3, pad=1),
        LayerSpec("l1", K=4, C=4, P=4, Q=4, R=3, S=3, pad=1),
    ]
    import repro.dse.explore as ex
    monkeypatch.setattr(
        ex, "describe",
        lambda name: type("D", (), {"layers": layers,
                                    "edges": chain_edges(layers)})())
    sp = tiny_space()
    dcfg = tiny_dcfg()
    p = sp.default()
    rec = evaluate_point(sp, p, dcfg)
    ref = optimize_network(layers, chain_edges(layers), sp.build(p),
                           dcfg.search_config())
    assert rec["total_ns"] == ref.total_ns
