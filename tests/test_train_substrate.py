"""Training substrate: optimizer, checkpointing (incl. corruption +
auto-resume), trainer loop with failure injection, data pipeline, serve
engine."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # trainer-loop / serve-engine XLA compiles

from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticStream
from repro.models import model_zoo
from repro.serve.engine import Engine, ServeConfig
from repro.train import checkpoint as ckpt
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state,
                                   lr_schedule, topk_compress)
from repro.train.trainer import Trainer, TrainerConfig
from repro.launch.mesh import make_host_mesh


# -- optimizer ----------------------------------------------------------------

def test_lr_schedule():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.asarray(110))) == pytest.approx(
        0.1, abs=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    cn = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in
                            jax.tree_util.tree_leaves(clipped))))
    assert cn == pytest.approx(1.0, rel=1e-5)


def test_adamw_decreases_quadratic():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_topk_compress():
    g = jnp.asarray(np.arange(100, dtype=np.float32) - 50)
    c = topk_compress(g, frac=0.1)
    assert int((c != 0).sum()) <= 12
    assert float(jnp.abs(c).max()) == float(jnp.abs(g).max())


# -- checkpointing ------------------------------------------------------------

def _tiny_tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "n": {"b": jnp.ones((4,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tiny_tree()
    ckpt.save(str(tmp_path), 7, {"params": t}, meta={"x": 1})
    res = ckpt.restore(str(tmp_path), {"params": jax.eval_shape(
        lambda: t)})
    assert res is not None
    step, trees, meta = res
    assert step == 7 and meta["x"] == 1
    np.testing.assert_array_equal(np.asarray(trees["params"]["a"]),
                                  np.asarray(t["a"]))
    assert trees["params"]["n"]["b"].dtype == jnp.bfloat16


def test_checkpoint_skips_corrupt_latest(tmp_path):
    t = _tiny_tree()
    ckpt.save(str(tmp_path), 1, {"params": t})
    ckpt.save(str(tmp_path), 2, {"params": t})
    # corrupt the newest file
    newest = sorted(glob.glob(str(tmp_path / "*.rpck")))[-1]
    with open(newest, "wb") as f:
        f.write(b"garbage")
    res = ckpt.restore(str(tmp_path), {"params": jax.eval_shape(
        lambda: t)})
    assert res is not None and res[0] == 1  # fell back to older valid


def test_checkpoint_missing_codec_raises(tmp_path):
    """A checkpoint written with a codec this env lacks must raise loudly,
    not be skipped as corrupt (silent skip would roll training back)."""
    t = _tiny_tree()
    ckpt.save(str(tmp_path), 3, {"params": t})
    # forge a newer zstd-magic file; without zstandard installed restore
    # must raise MissingCodecError instead of falling back to step 3
    import struct
    blob = ckpt._MAGIC + struct.pack("<Q", 4) + b"zzzz"
    with open(str(tmp_path / "ckpt_00000009.rpck"), "wb") as f:
        f.write(blob)
    template = {"params": jax.eval_shape(lambda: t)}
    if ckpt.zstandard is None:
        with pytest.raises(ckpt.MissingCodecError):
            ckpt.restore(str(tmp_path), template)
    else:  # codec available: the forged file is plain corruption -> skip
        res = ckpt.restore(str(tmp_path), template)
        assert res is not None and res[0] == 3


def test_checkpoint_prune(tmp_path):
    t = _tiny_tree()
    for s in range(5):
        ckpt.save(str(tmp_path), s, {"params": t})
    ckpt.prune(str(tmp_path), keep=2)
    assert len(glob.glob(str(tmp_path / "*.rpck"))) == 2
    assert ckpt.latest_step(str(tmp_path)) == 4


# -- data pipeline ------------------------------------------------------------

def test_data_stateless_random_access():
    cfg = get_config("olmo_1b", smoke=True)
    d = DataConfig(seed=9, batch=4, seq=32)
    s1 = SyntheticStream(cfg, d)
    s2 = SyntheticStream(cfg, d)
    b1, b2 = s1.batch_at(17), s2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch_at(17)["tokens"],
                              s1.batch_at(18)["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < cfg.vocab
    # labels are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:],
                                  b1["labels"][:, :-1])


def test_data_shards_differ():
    cfg = get_config("olmo_1b", smoke=True)
    d = DataConfig(seed=9, batch=4, seq=32)
    a = SyntheticStream(cfg, d, shard=0, n_shards=2).batch_at(3)
    b = SyntheticStream(cfg, d, shard=1, n_shards=2).batch_at(3)
    assert a["tokens"].shape[0] == 2
    assert not np.array_equal(a["tokens"], b["tokens"])


# -- trainer: loss goes down + failure injection / resume --------------------

@pytest.fixture(scope="module")
def tiny_trainer_args(tmp_path_factory):
    cfg = get_config("olmo_1b", smoke=True)
    mesh = make_host_mesh(data=1, model=1)
    return cfg, mesh


def test_trainer_loss_decreases(tiny_trainer_args, tmp_path):
    cfg, mesh = tiny_trainer_args
    tr = Trainer(cfg, mesh,
                 opt_cfg=OptimizerConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=30),
                 tcfg=TrainerConfig(steps=30, log_every=5),
                 dcfg=DataConfig(batch=8, seq=64))
    tr.run()
    hist = tr.metrics_history
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.98


def test_trainer_failure_restart_resumes(tiny_trainer_args, tmp_path):
    cfg, mesh = tiny_trainer_args
    kw = dict(
        opt_cfg=OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=12),
        dcfg=DataConfig(batch=4, seq=32))
    t1 = Trainer(cfg, mesh, tcfg=TrainerConfig(
        steps=12, ckpt_dir=str(tmp_path), ckpt_every=4, log_every=4),
        **kw)
    with pytest.raises(RuntimeError, match="injected failure"):
        t1.run(fail_at=9)
    assert ckpt.latest_step(str(tmp_path)) == 8
    # restart: must resume from step 8, not 0
    t2 = Trainer(cfg, mesh, tcfg=TrainerConfig(
        steps=12, ckpt_dir=str(tmp_path), ckpt_every=4, log_every=4),
        **kw)
    t2.run()
    assert t2.step == 12
    assert ckpt.latest_step(str(tmp_path)) == 12


# -- serve engine -------------------------------------------------------------

@pytest.mark.parametrize("arch", ["olmo_1b", "mamba2_780m",
                                  "granite_moe_1b_a400m"])
def test_engine_generates(arch):
    cfg = get_config(arch, smoke=True)
    params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, scfg=ServeConfig(max_seq=64,
                                               max_new_tokens=8))
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (2, 16))
    out = eng.generate(prompts.astype(np.int32))
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()
    # greedy decoding is deterministic
    out2 = eng.generate(prompts.astype(np.int32))
    np.testing.assert_array_equal(out, out2)


def test_engine_prefill_decode_consistency():
    """Greedy continuation from prefill equals teacher-forced argmax of
    the full forward at the same position (KV-cache correctness)."""
    cfg = get_config("olmo_1b", smoke=True)
    params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab, (1, 12)).astype(np.int32)
    logits_full, _ = model_zoo.forward(cfg, params,
                                       {"tokens": jnp.asarray(prompt)})
    want = int(jnp.argmax(logits_full[0, -1]))
    logits_pf, _ = model_zoo.prefill(cfg, params, jnp.asarray(prompt),
                                     max_seq=32)
    got = int(jnp.argmax(logits_pf[0]))
    assert got == want
