"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret
mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests prefer hypothesis; fall back to fixed seeded draws
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _prop_fallback import given, settings, st

pytestmark = pytest.mark.kernels  # JAX/Pallas compile-heavy (see pytest.ini)

from repro.kernels.flash_attn import attention_ref, flash_attention_op
from repro.kernels.fused_mlp import fused_mlp_op, fused_mlp_ref
from repro.kernels.ssd_scan import ssd_ref, ssd_scan_op


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused MLP
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,f,tm,tf", [
    (128, 256, 512, 64, 128),
    (256, 128, 256, 128, 256),
    (64, 64, 128, 64, 64),
])
def test_fused_mlp_shapes(dtype, m, k, f, tm, tf):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = (jax.random.normal(ks[0], (m, k)) * 0.5).astype(dtype)
    w1 = (jax.random.normal(ks[1], (k, f)) * 0.05).astype(dtype)
    w3 = (jax.random.normal(ks[2], (k, f)) * 0.05).astype(dtype)
    w2 = (jax.random.normal(ks[3], (f, k)) * 0.05).astype(dtype)
    y = fused_mlp_op(x, w1, w3, w2, tm=tm, tf=tf, interpret=True)
    yr = fused_mlp_ref(x, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@given(mi=st.integers(1, 4), ki=st.integers(1, 4), fi=st.integers(1, 4),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_fused_mlp_property(mi, ki, fi, seed):
    m, k, f = 64 * mi, 64 * ki, 64 * fi
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (m, k)) * 0.5
    w1 = jax.random.normal(ks[1], (k, f)) * 0.05
    w3 = jax.random.normal(ks[2], (k, f)) * 0.05
    w2 = jax.random.normal(ks[3], (f, k)) * 0.05
    y = fused_mlp_op(x, w1, w3, w2, tm=64, tf=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(fused_mlp_ref(x, w1, w3, w2)),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,kv,sq,sk,hd", [
    (2, 4, 2, 128, 128, 64),    # GQA g=2
    (1, 8, 1, 64, 256, 32),     # MQA, rectangular
    (2, 2, 2, 256, 256, 128),   # MHA
])
def test_flash_attention_shapes(dtype, causal, b, h, kv, sq, sk, hd):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b * h, sq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b * kv, sk, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b * kv, sk, hd)).astype(dtype)
    y = flash_attention_op(q, k, v, causal=causal, tq=64, tk=64,
                           interpret=True)
    yr = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               **_tol(dtype))


@given(sq=st.sampled_from([64, 128, 192]),
       sk=st.sampled_from([64, 128, 256]),
       g=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(sq, sk, g, seed):
    if sq > sk:  # causal with sq > sk is ill-posed in this layout
        sq = sk
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (2 * g, sq, 32))
    k = jax.random.normal(ks[1], (2, sk, 32))
    v = jax.random.normal(ks[2], (2, sk, 32))
    y = flash_attention_op(q, k, v, causal=True, tq=64, tk=64,
                           interpret=True)
    yr = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_flash():
    """Kernel agrees with the model-layer einsum flash implementation."""
    from repro.models.attention import flash_attention as model_flash
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    b, s, h, kv, hd = 2, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    ym = model_flash(q, k, v, causal=True)
    # kernel layout: [B*KV*G, S, hd] with q grouped (b, kv, g)
    qk = q.reshape(b, s, kv, h // kv, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(b * h, s, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    yk = flash_attention_op(qk, kk, vk, causal=True, tq=64, tk=64,
                            interpret=True)
    yk = yk.reshape(b, kv, h // kv, s, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(b, s, h, hd)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ym),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (3, 64, 16, 8, 16),
    (2, 128, 32, 16, 32),
    (1, 64, 64, 128, 64),   # mamba2-780m head geometry
])
def test_ssd_scan_shapes(dtype, bh, s, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (bh, s, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s, 1))).astype(
        dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (bh, 1, 1)) * 0.2).astype(
        dtype)
    bm = jax.random.normal(ks[3], (bh, s, n)).astype(dtype)
    cm = jax.random.normal(ks[4], (bh, s, n)).astype(dtype)
    y = ssd_scan_op(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yr = ssd_ref(x, dt, a, bm, cm)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)


@given(chunks=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2 ** 16))
@settings(max_examples=8, deadline=None)
def test_ssd_scan_property_chunk_invariance(chunks, seed):
    """Output must not depend on the chunk size (state handoff exact)."""
    bh, s, p, n = 2, 64, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (bh, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s, 1)))
    a = -jnp.exp(jax.random.normal(ks[2], (bh, 1, 1)) * 0.2)
    bm = jax.random.normal(ks[3], (bh, s, n))
    cm = jax.random.normal(ks[4], (bh, s, n))
    y16 = ssd_scan_op(x, dt, a, bm, cm, chunk=16, interpret=True)
    y_var = ssd_scan_op(x, dt, a, bm, cm, chunk=16 * chunks,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y_var),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_matches_model_ssd():
    """Kernel agrees with models.ssm.ssd_chunked (group expansion)."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, g, n = 2, 64, 4, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    bm = jax.random.normal(ks[3], (b, s, g, n))
    cm = jax.random.normal(ks[4], (b, s, g, n))
    ym, _ = ssd_chunked(x, dt, a, bm, cm, chunk=16)
    # kernel layout [B*H, S, *] with group-expanded B/C
    xk = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtk = dt.transpose(0, 2, 1).reshape(b * h, s, 1)
    ak = jnp.broadcast_to(a[None, :], (b, h)).reshape(b * h, 1, 1)
    rep = h // g
    bk = jnp.repeat(bm, rep, axis=2).transpose(0, 2, 1, 3).reshape(
        b * h, s, n)
    ck = jnp.repeat(cm, rep, axis=2).transpose(0, 2, 1, 3).reshape(
        b * h, s, n)
    yk = ssd_scan_op(xk, dtk, ak, bk, ck, chunk=16, interpret=True)
    yk = yk.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ym),
                               rtol=2e-4, atol=2e-4)
