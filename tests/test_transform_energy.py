"""Energy accounting of the overlap transformation (DESIGN.md Section 9).

Property tests pin the algebra of ``transform_schedule``'s
``moved_bytes`` / ``move_energy_pj`` extension (zero-move => zero
energy, monotonicity in the tile footprint, latency invariance vs the
pre-energy code path), and a golden regression pins the per-layer
compute/IO/move energy split of resnet18 on the paper's default
``dram_pim()`` so perf-model refactors cannot silently drift the energy
model.
"""
import numpy as np
import pytest

try:  # property tests prefer hypothesis; fall back to fixed seeded draws
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _prop_fallback import given, settings, st

from repro.core import (SearchConfig, combine_objective, describe,
                        dram_pim, evaluate_chain, heuristic_mapping,
                        move_energy_pj, transform_schedule)


def ready_matrix(seed: int, nb: int, nt: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    return rng.uniform(0.0, 50.0, size=(nb, nt))


# ---------------------------------------------------------------------------
# Properties of transform_schedule's energy accounting.
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10 ** 6), nt=st.integers(1, 9))
@settings(max_examples=15, deadline=None)
def test_single_bank_never_moves_never_charges(seed, nt):
    """nb == 1: round-robin re-allocation cannot re-home anything, so
    moved_frac == 0 => moved_bytes == move_energy_pj == 0 regardless of
    the footprint."""
    tr = transform_schedule(ready_matrix(seed, 1, nt), step_ns=3.0,
                            tile_move_ns=1.0, tile_bytes=4096.0,
                            move_pj_per_byte=6.4)
    assert tr.moved_frac == 0.0
    assert tr.moved_bytes == 0.0
    assert tr.move_energy_pj == 0.0


@given(seed=st.integers(0, 10 ** 6), nb=st.integers(1, 4),
       nt=st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_zero_footprint_zero_energy(seed, nb, nt):
    """moved_frac may be > 0, but tile_bytes == 0 charges nothing (the
    default — i.e. every pre-energy call site)."""
    tr = transform_schedule(ready_matrix(seed, nb, nt), step_ns=2.0,
                            tile_move_ns=1.5, move_pj_per_byte=6.4)
    assert tr.moved_bytes == 0.0
    assert tr.move_energy_pj == 0.0


@given(seed=st.integers(0, 10 ** 6), nb=st.integers(1, 4),
       nt=st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_move_energy_monotone_in_tile_bytes(seed, nb, nt):
    ready = ready_matrix(seed, nb, nt)
    prev = -1.0
    for tb in (0.0, 1.0, 64.0, 4096.0):
        tr = transform_schedule(ready, step_ns=2.0, tile_move_ns=1.0,
                                tile_bytes=tb, move_pj_per_byte=6.4)
        assert tr.move_energy_pj >= prev
        prev = tr.move_energy_pj


@given(seed=st.integers(0, 10 ** 6), nb=st.integers(1, 4),
       nt=st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_latency_results_invariant_under_tile_bytes(seed, nb, nt):
    """The schedule (end/finish/moved_frac) must be byte-for-byte what
    the pre-energy code path produced, for ANY footprint: tile_bytes
    feeds accounting only."""
    ready = ready_matrix(seed, nb, nt)
    base = transform_schedule(ready, step_ns=2.0, tile_move_ns=1.0)
    for tb in (0.0, 64.0, 4096.0):
        tr = transform_schedule(ready, step_ns=2.0, tile_move_ns=1.0,
                                tile_bytes=tb, move_pj_per_byte=6.4)
        assert tr.end_ns == base.end_ns
        assert np.array_equal(tr.finish_ns, base.finish_ns)
        assert tr.moved_frac == base.moved_frac


@given(seed=st.integers(0, 10 ** 6), nb=st.integers(1, 4),
       nt=st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_moved_bytes_and_energy_consistency(seed, nb, nt):
    """moved_bytes == (#moved) * tile_bytes and energy == bytes * pJ/B;
    a constant per-space footprint array must equal the scalar path."""
    ready = ready_matrix(seed, nb, nt)
    tb, e = 96.0, 6.4
    tr = transform_schedule(ready, step_ns=2.0, tile_move_ns=1.0,
                            tile_bytes=tb, move_pj_per_byte=e)
    n_moved = round(tr.moved_frac * ready.size)
    assert tr.moved_bytes == n_moved * tb
    assert tr.move_energy_pj == tr.moved_bytes * e
    arr = transform_schedule(ready, step_ns=2.0, tile_move_ns=1.0,
                             tile_bytes=np.full((nb, nt), tb),
                             move_pj_per_byte=e)
    assert arr.moved_bytes == tr.moved_bytes
    assert arr.move_energy_pj == tr.move_energy_pj


def test_per_space_footprints_bounded_by_extremes():
    """Heterogeneous per-space footprints: moved_bytes lies between the
    all-min and all-max scalar cases (the accounting really reads the
    per-space array, not an average)."""
    ready = ready_matrix(3, 3, 7)
    rng = np.random.RandomState(7)
    tb = rng.uniform(10.0, 100.0, size=(3, 7))
    got = transform_schedule(ready, step_ns=2.0, tile_bytes=tb)
    lo = transform_schedule(ready, step_ns=2.0, tile_bytes=float(tb.min()))
    hi = transform_schedule(ready, step_ns=2.0, tile_bytes=float(tb.max()))
    assert lo.moved_bytes <= got.moved_bytes <= hi.moved_bytes
    if got.moved_frac > 0:
        assert lo.moved_bytes < hi.moved_bytes


# ---------------------------------------------------------------------------
# Objective scalarization + the perf-model hook.
# ---------------------------------------------------------------------------

def test_combine_objective_semantics():
    lat, en = 1000.0, 250.0
    assert combine_objective("latency", lat, en) == lat
    assert combine_objective("energy", lat, en) == en
    assert combine_objective("edp", lat, en) == lat * en
    assert combine_objective("blend", lat, en, 0.0) == lat
    assert combine_objective("blend", lat, en, 1.0) == en
    mid = combine_objective("blend", lat, en, 0.5)
    assert min(lat, en) <= mid <= max(lat, en)
    with pytest.raises(ValueError):
        combine_objective("nonsense", lat, en)


def test_move_energy_hook_matches_io_energy_scale():
    arch = dram_pim()
    assert move_energy_pj(arch, 1.0) == 8 * arch.timing.e_io
    assert move_energy_pj(arch, 100.0) == 100 * 8 * arch.timing.e_io


def test_layer_perf_energy_decomposition():
    """energy_pj must stay exactly compute + IO (the pre-energy value),
    with the split and the transform inputs exposed alongside."""
    from repro.core import analyze
    arch = dram_pim()
    desc = describe("resnet18")
    m = heuristic_mapping(desc.layers[0], arch, 16384)
    p = analyze(m)
    assert p.energy_pj == p.compute_energy_pj + p.io_energy_pj
    assert p.compute_energy_pj > 0 and p.io_energy_pj > 0
    assert p.tile_bytes > 0
    assert p.move_pj_per_byte == move_energy_pj(arch, 1.0)
    # tile time and tile energy describe the same footprint
    ext = m.tile_extent
    tile_out = ext["K"] * ext["P"] * ext["Q"]
    assert p.tile_bytes == tile_out * arch.word_bytes


# ---------------------------------------------------------------------------
# Golden regression: resnet18 on dram_pim(), heuristic mappings,
# transform mode. Pins the compute/IO/move energy split per layer at the
# current model values — any perf_model/transform refactor that shifts
# the energy model must update these numbers *consciously*.
# ---------------------------------------------------------------------------

GOLDEN_RESNET18_DRAM = [
    # (layer, compute_energy_pj, io_energy_pj, move_energy_pj)
    ("conv1", 118538524016640.0, 10276044.8, 0.0),
    ("s1b0c1", 116119370465280.0, 2569011.2, 2384793.6),
    ("s1b0c2", 116119370465280.0, 2569011.2, 2388684.8000000003),
    ("s1b1c1", 116119370465280.0, 2569011.2, 2390937.6),
    ("s1b1c2", 116119370465280.0, 2569011.2, 2385920.0),
    ("s2b0c1", 58059685232640.0, 1284505.6, 1192755.2),
    ("s2b0c2", 116119370465280.0, 1284505.6, 1184768.0),
    ("s2b0ds", 6451076136960.0, 1284505.6, 127795.20000000001),
    ("s2b1c1", 116119370465280.0, 1284505.6, 1184768.0),
    ("s2b1c2", 116119370465280.0, 1284505.6, 1184768.0),
    ("s3b0c1", 58059685232640.0, 642252.8, 592076.8),
    ("s3b0c2", 116119370465280.0, 642252.8, 596377.6),
    ("s3b0ds", 6451076136960.0, 642252.8, 596377.6),
    ("s3b1c1", 116119370465280.0, 642252.8, 596377.6),
    ("s3b1c2", 116119370465280.0, 642252.8, 596377.6),
    ("s4b0c1", 58059685232640.0, 321126.4, 298188.8),
    ("s4b0c2", 116119370465280.0, 321126.4, 298112.0),
    ("s4b0ds", 6451076136960.0, 321126.4, 280985.60000000003),
    ("s4b1c1", 116119370465280.0, 321126.4, 298112.0),
    ("s4b1c2", 116119370465280.0, 321126.4, 298112.0),
]


def _golden_chain():
    arch = dram_pim()
    desc = describe("resnet18")
    maps = [heuristic_mapping(l, arch, 16384) for l in desc.layers]
    return evaluate_chain(maps, desc.edges, "transform")


def test_golden_resnet18_energy_split():
    res = _golden_chain()
    assert len(res.layers) == len(GOLDEN_RESNET18_DRAM)
    for lr, (name, compute, io, move) in zip(res.layers,
                                             GOLDEN_RESNET18_DRAM):
        assert lr.mapping.layer.name == name
        assert lr.perf.compute_energy_pj == pytest.approx(compute,
                                                          rel=1e-12)
        assert lr.perf.io_energy_pj == pytest.approx(io, rel=1e-12)
        assert lr.move_energy_pj == pytest.approx(move, rel=1e-12)
        assert lr.energy_pj == lr.perf.energy_pj + lr.move_energy_pj


def test_golden_resnet18_summary_breakdown():
    """NetworkResult.summary() reports the same decomposition, summed."""
    res = _golden_chain()
    s = res.summary()
    exp_compute = sum(g[1] for g in GOLDEN_RESNET18_DRAM)
    exp_io = sum(g[2] for g in GOLDEN_RESNET18_DRAM)
    exp_move = sum(g[3] for g in GOLDEN_RESNET18_DRAM)
    assert s["compute_energy_pj"] == pytest.approx(exp_compute, rel=1e-12)
    assert s["io_energy_pj"] == pytest.approx(exp_io, rel=1e-12)
    assert s["move_energy_pj"] == pytest.approx(exp_move, rel=1e-12)
    assert s["energy_pj"] == pytest.approx(
        exp_compute + exp_io + exp_move, rel=1e-12)
    assert s["edp_ns_pj"] == pytest.approx(s["total_ns"] * s["energy_pj"],
                                           rel=1e-12)
    # skip-connection layers move real data in transform mode; the stem
    # (no producer) moves nothing — the split is not vacuous
    assert s["move_energy_pj"] > 0
    assert res.layers[0].move_energy_pj == 0.0


def test_search_config_rejects_unknown_objective():
    with pytest.raises(AssertionError):
        SearchConfig(objective="joules")
    with pytest.raises(AssertionError):
        SearchConfig(objective="blend", blend_alpha=1.5)
