"""Deterministic stand-ins for ``hypothesis`` decorators.

The container may not ship ``hypothesis``; skipping the whole module would
drop the C1/C2 analytical-vs-exhaustive oracle tests entirely. Instead the
property tests import these shims as a fallback: ``@given`` becomes a
``pytest.mark.parametrize`` over a fixed, seeded sample of the strategy
space (same assertions, deterministic inputs). With ``hypothesis``
installed the real decorators are used and these shims are never imported.
"""
import random

import pytest


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class _St:
    @staticmethod
    def integers(lo, hi):
        return _Strategy(lambda rng: rng.randint(lo, hi))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: rng.choice(opts))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))


st = _St()


def settings(max_examples=10, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    """Expand to a parametrize over ``max_examples`` seeded draws."""
    def deco(fn):
        n = getattr(fn, "_max_examples", 10)
        rng = random.Random(0xFA57)
        names = sorted(strategies)
        cases = [tuple(strategies[k].draw(rng) for k in names)
                 for _ in range(n)]
        if len(names) == 1:
            cases = [c[0] for c in cases]
        return pytest.mark.parametrize(",".join(names), cases)(fn)
    return deco
