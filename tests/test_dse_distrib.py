"""Distributed sweep subsystem tests (repro.dse.distrib).

The heart of the suite is the differential contract of DESIGN.md
Section 10: an N-worker distributed sweep over a shared directory must
reproduce the single-host serial sweep's records and Pareto frontier
*byte-identically*, for any N, and a resumed sweep must dispatch zero
new mapping searches. Workers here run in threads (the protocol —
shards, manifests, leases, stealing — is identical to process mode,
which the CI smoke leg and the scaling benchmark exercise for real);
searches run on a tiny conv chain so the module stays in the fast core
loop.
"""
import json
import os
import threading
import time

import pytest

from repro.core import LayerSpec, chain_edges
from repro.dse import (DSEConfig, DistribConfig, RunJournal,
                       SharedDirBackend, run_distributed, run_dse)
from repro.dse.distrib import (LeaseBoard, WorkerConfig, batch_id_for,
                               list_manifests, post_manifest,
                               request_stop, stop_requested, worker_loop)
from repro.dse.distrib.lease import ManifestCache
from repro.dse.explore import key_for, proposal_stream
from repro.dse.space import ParamSpace

TINY_LAYERS = [
    LayerSpec("l0", K=8, C=4, P=8, Q=8, R=3, S=3, pad=1),
    LayerSpec("l1", K=8, C=8, P=8, Q=8, R=3, S=3, pad=1),
]


@pytest.fixture
def tiny_net(monkeypatch):
    """Patch the network lookup everywhere evaluations happen (serial
    evaluator and worker loops share explore._search_arch)."""
    import repro.dse.explore as ex

    desc = type("D", (), {"layers": TINY_LAYERS,
                          "edges": chain_edges(TINY_LAYERS)})()
    monkeypatch.setattr(ex, "describe", lambda name: desc)


def tiny_space() -> ParamSpace:
    return ParamSpace(
        family="dram_pim",
        axes={
            "channels_per_layer": (1, 2),
            "banks_per_channel": (2, 4),
            "columns_per_bank": (64, 128),
        },
        defaults={"channels_per_layer": 2, "banks_per_channel": 2,
                  "columns_per_bank": 64},
    )


def tiny_dcfg(**kw) -> DSEConfig:
    base = dict(network="tiny", mode="transform", budget=6,
                n_candidates=3, max_steps=256, seed=0, explorer="evolve",
                population=3)
    base.update(kw)
    return DSEConfig(**base)


def strip_wall(rec):
    return {k: v for k, v in rec.items() if k != "wall_s"}


# ---------------------------------------------------------------------------
# The differential contract: N workers == serial, bit-exactly.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_workers", [1, 2, 4])
def test_distributed_matches_serial_bit_exactly(n_workers, tiny_net,
                                                tmp_path):
    sp = tiny_space()
    dcfg = tiny_dcfg()
    serial = run_dse(dcfg, space=sp, journal=RunJournal())
    dist = DistribConfig(root=str(tmp_path / f"root{n_workers}"),
                         n_workers=n_workers, worker_mode="thread",
                         timeout_s=60.0)
    res = run_distributed(dcfg, dist, space=sp)
    assert res.stats["proposed"] == serial.stats["proposed"]
    assert res.stats["evaluated"] == serial.stats["evaluated"]
    # frontier: byte-identical canonical serialization
    assert res.frontier.canonical_json() == serial.frontier.canonical_json()
    # records: identical content in identical proposal order
    # (wall_s is the one honest wall-clock field)
    assert [strip_wall(r) for r in res.records] == \
        [strip_wall(r) for r in serial.records]


@pytest.mark.parametrize("explorer", ["grid", "random"])
def test_distributed_one_shot_explorers_match_serial(explorer, tiny_net,
                                                     tmp_path):
    sp = tiny_space()
    dcfg = tiny_dcfg(explorer=explorer, budget=5)
    serial = run_dse(dcfg, space=sp, journal=RunJournal())
    res = run_distributed(
        dcfg, DistribConfig(root=str(tmp_path / "root"), n_workers=2,
                            worker_mode="thread", timeout_s=60.0),
        space=sp)
    assert res.frontier.canonical_json() == serial.frontier.canonical_json()
    assert [strip_wall(r) for r in res.records] == \
        [strip_wall(r) for r in serial.records]


def test_distributed_resume_dispatches_nothing(tiny_net, tmp_path):
    """Re-running a finished sweep over the same shared dir serves every
    point from the merged journal: zero manifests, zero evaluations."""
    sp = tiny_space()
    dcfg = tiny_dcfg()
    root = str(tmp_path / "root")
    first = run_distributed(
        dcfg, DistribConfig(root=root, n_workers=2, worker_mode="thread",
                            timeout_s=60.0), space=sp)
    assert first.stats["evaluated"] == dcfg.budget
    again = run_distributed(
        dcfg, DistribConfig(root=root, n_workers=2, worker_mode="thread",
                            timeout_s=60.0), space=sp)
    assert again.stats["evaluated"] == 0
    assert again.stats["from_journal"] == dcfg.budget
    assert again.stats["batches"] == 0
    assert again.frontier.canonical_json() == first.frontier.canonical_json()


def test_distributed_external_mode_with_manual_worker(tiny_net, tmp_path):
    """external worker_mode spawns nothing; a worker started separately
    (here: a thread running the real worker_loop) supplies the compute."""
    sp = tiny_space()
    dcfg = tiny_dcfg(explorer="grid", budget=4)
    root = str(tmp_path / "root")
    os.makedirs(root, exist_ok=True)
    t = threading.Thread(
        target=worker_loop,
        args=(WorkerConfig(root=root, worker_id="ext-0", poll_s=0.01),),
        daemon=True)
    t.start()
    res = run_distributed(
        dcfg, DistribConfig(root=root, n_workers=0, worker_mode="external",
                            timeout_s=60.0), space=sp)
    t.join(timeout=30.0)
    assert not t.is_alive()          # STOP shut the external worker down
    assert res.stats["evaluated"] == 4
    ref = run_dse(dcfg, space=sp, journal=RunJournal())
    assert res.frontier.canonical_json() == ref.frontier.canonical_json()


# ---------------------------------------------------------------------------
# Lease expiry / work stealing.
# ---------------------------------------------------------------------------

def test_lease_claim_release_and_done(tmp_path):
    root = str(tmp_path)
    a = LeaseBoard(root, "a", ttl_s=60.0)
    b = LeaseBoard(root, "b", ttl_s=60.0)
    assert a.try_claim("batch1")
    assert not b.try_claim("batch1")      # live lease blocks peers
    a.mark_done("batch1")
    a.release("batch1")
    assert not b.try_claim("batch1")      # done batches are never claimed
    assert b.is_done("batch1")


def test_expired_lease_is_stolen_exactly_once(tmp_path):
    root = str(tmp_path)
    dead = LeaseBoard(root, "dead", ttl_s=0.0)    # expires immediately
    assert dead.try_claim("batch1")
    b = LeaseBoard(root, "b", ttl_s=60.0)
    c = LeaseBoard(root, "c", ttl_s=60.0)
    got_b = b.try_claim("batch1")
    got_c = c.try_claim("batch1")
    assert got_b != got_c                 # exactly one thief wins
    assert b.n_stolen + c.n_stolen == 1
    winner = b if got_b else c
    lease = winner.read_lease("batch1")
    assert lease["worker"] == winner.worker_id
    assert lease["expires_at"] > time.time()


def test_killed_workers_batch_is_restolen_and_completed(tiny_net,
                                                        tmp_path):
    """The acceptance-criteria crash story: a worker claims a batch and
    dies (its lease is never renewed); a live worker steals the expired
    lease, re-evaluates, publishes, and the sweep completes."""
    sp = tiny_space()
    dcfg = tiny_dcfg(explorer="grid", budget=3)
    root = str(tmp_path / "root")
    os.makedirs(root, exist_ok=True)

    # post one batch manifest by hand, exactly as the coordinator would
    pts = [sp.default()] + list(sp.enumerate())[:2]
    import dataclasses as dc
    items = []
    for p in pts:
        arch = sp.build(p)
        items.append({"key": key_for(dcfg, arch.to_key()),
                      "family": p.family, "point": p.as_dict(),
                      "arch": arch.to_dict()})
    bid = batch_id_for([it["key"] for it in items])
    post_manifest(root, {"batch_id": bid, "dcfg": dc.asdict(dcfg),
                         "items": items})

    # the doomed worker claims with a tiny ttl... and dies silently
    doomed = LeaseBoard(root, "doomed", ttl_s=0.05)
    assert doomed.try_claim(bid)
    time.sleep(0.06)                      # lease expires un-renewed

    stats = worker_loop(WorkerConfig(root=root, worker_id="live",
                                     poll_s=0.01, lease_ttl_s=30.0,
                                     max_idle_s=0.5))
    assert stats["stolen"] == 1
    assert stats["evaluated"] == 3
    board = LeaseBoard(root, "observer", ttl_s=1.0)
    assert board.is_done(bid)
    merged = RunJournal(backend=SharedDirBackend(root, writer_id="obs"))
    assert all(it["key"] in merged for it in items)
    # and the stolen work is bit-identical to a serial evaluation
    ref = run_dse(dcfg, space=sp, journal=RunJournal())
    by_key = {r["key"]: r for r in ref.records}
    for it in items:
        assert strip_wall(merged.get(it["key"])) == \
            strip_wall(by_key[it["key"]])


def test_worker_skips_batches_already_in_merged_journal(tiny_net,
                                                        tmp_path):
    """Dedup-before-work: if every key of a manifest is already in the
    merged journal, a worker marks it done without evaluating."""
    sp = tiny_space()
    dcfg = tiny_dcfg(explorer="grid", budget=2)
    root = str(tmp_path / "root")
    # evaluate the sweep once, distributed, to fill the shared journal
    run_distributed(dcfg, DistribConfig(root=root, n_workers=1,
                                        worker_mode="thread",
                                        timeout_s=60.0), space=sp)
    # repost a manifest for already-journaled keys, with a fresh id
    import dataclasses as dc
    pts = [sp.default()]
    arch = sp.build(pts[0])
    items = [{"key": key_for(dcfg, arch.to_key()), "family": pts[0].family,
              "point": pts[0].as_dict(), "arch": arch.to_dict()}]
    bid = batch_id_for([it["key"] for it in items] + ["repost"])
    os.remove(os.path.join(root, "STOP"))
    post_manifest(root, {"batch_id": bid, "dcfg": dc.asdict(dcfg),
                         "items": items})
    stats = worker_loop(WorkerConfig(root=root, worker_id="dedup",
                                     poll_s=0.01, max_idle_s=0.5))
    assert stats["evaluated"] == 0
    assert stats["skipped_done"] >= 1
    assert LeaseBoard(root, "o", ttl_s=1.0).is_done(bid)


# ---------------------------------------------------------------------------
# Protocol plumbing.
# ---------------------------------------------------------------------------

def test_manifest_publish_and_cache(tmp_path):
    root = str(tmp_path)
    m1 = {"batch_id": "b1", "items": [], "dcfg": {}}
    m2 = {"batch_id": "b2", "items": [], "dcfg": {}}
    post_manifest(root, m1)
    cache = ManifestCache(root)
    assert [m["batch_id"] for m in cache.scan()] == ["b1"]
    post_manifest(root, m2)
    assert sorted(m["batch_id"] for m in cache.scan()) == ["b1", "b2"]
    assert list_manifests(root) == cache.scan()


def test_stop_protocol(tmp_path):
    root = str(tmp_path)
    assert not stop_requested(root)
    request_stop(root)
    assert stop_requested(root)
    # a STOP already present when the worker starts is *stale* (left by
    # a previous sweep on a reused dir): the worker must not exit on it,
    # or workers started before their coordinator would die instantly
    stats = worker_loop(WorkerConfig(root=root, worker_id="w",
                                     poll_s=0.01, max_idle_s=0.3))
    assert stats["evaluated"] == 0      # idled out, not stopped


def test_fresh_stop_overrides_stale_one(tmp_path):
    """A worker that started under a stale STOP still honors the *next*
    STOP (fresh token) posted by its coordinator."""
    root = str(tmp_path)
    request_stop(root)                  # stale leftover
    done = {}

    def run():
        done["stats"] = worker_loop(WorkerConfig(root=root, worker_id="w",
                                                 poll_s=0.01))
    t = threading.Thread(target=run, daemon=True)
    t.start()
    time.sleep(0.15)
    assert t.is_alive()                 # ignoring the stale STOP
    request_stop(root)                  # fresh token
    t.join(timeout=10.0)
    assert not t.is_alive()
    assert done["stats"]["evaluated"] == 0


def test_wedged_compute_gate_degrades_but_stays_live(tiny_net, tmp_path):
    """A compute gate whose every holder crashed (slots never released)
    must not wedge the fleet: after repeated failed acquires the worker
    proceeds ungated, so leases still get stolen and work completes."""

    class WedgedGate:                     # acquire never succeeds
        def acquire(self, timeout=None):
            return False

        def release(self):                # pragma: no cover
            raise AssertionError("released a slot it never acquired")

    sp = tiny_space()
    dcfg = tiny_dcfg(explorer="grid", budget=2)
    root = str(tmp_path / "root")
    os.makedirs(root, exist_ok=True)
    import dataclasses as dc
    items = []
    for p in [sp.default()] + list(sp.enumerate())[:1]:
        arch = sp.build(p)
        items.append({"key": key_for(dcfg, arch.to_key()),
                      "family": p.family, "point": p.as_dict(),
                      "arch": arch.to_dict()})
    bid = batch_id_for([it["key"] for it in items])
    post_manifest(root, {"batch_id": bid, "dcfg": dc.asdict(dcfg),
                         "items": items})
    stats = worker_loop(WorkerConfig(root=root, worker_id="w",
                                     poll_s=0.01, max_idle_s=0.5,
                                     compute_gate=WedgedGate()))
    assert stats["evaluated"] == len(items)
    assert LeaseBoard(root, "o", ttl_s=1.0).is_done(bid)


def test_batch_ids_are_content_keyed():
    assert batch_id_for(["k1", "k2"]) == batch_id_for(["k1", "k2"])
    assert batch_id_for(["k1", "k2"]) != batch_id_for(["k2", "k1"])


def test_proposal_stream_protocol_enforced():
    """next_batch/observe must alternate, and budgets are respected."""
    sp = tiny_space()
    stream = proposal_stream(sp, tiny_dcfg(explorer="grid", budget=4))
    batch = stream.next_batch()
    assert len(batch) == 4
    with pytest.raises(AssertionError):
        stream.next_batch()              # observe() first
    stream.observe(batch, [{"point_key": p.key()} for p in batch])
    assert stream.next_batch() is None


def test_coordinator_raises_when_all_workers_die(tiny_net, tmp_path):
    """A sweep whose local workers all exited with work outstanding must
    fail loudly, not hang until the timeout."""

    class DeadHandle:
        def is_alive(self):
            return False

        def join(self, timeout=None):
            pass

    from repro.dse.distrib import coordinator as co
    sp = tiny_space()
    dcfg = tiny_dcfg(explorer="grid", budget=2)
    dist = DistribConfig(root=str(tmp_path / "root"), n_workers=2,
                         worker_mode="thread", timeout_s=60.0)
    orig = co._spawn_workers
    co._spawn_workers = lambda d: [DeadHandle()]
    try:
        with pytest.raises(RuntimeError, match="workers exited"):
            run_distributed(dcfg, dist, space=sp)
    finally:
        co._spawn_workers = orig
