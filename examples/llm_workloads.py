"""Quickstart: search PIM mappings for the LLM model zoo.

    PYTHONPATH=src python examples/llm_workloads.py
    PYTHONPATH=src python examples/llm_workloads.py \
        --scenario deepseek_moe_16b_smoke:prefill@64

Lowers one zoo scenario (``repro.workloads`` — see DESIGN.md Section
15) into a 7D loop-nest network, prints its layer/edge structure, and
runs the overlap-driven mapping search on both the prefill and the
decode shape of the same model, showing how the two phases stress the
mapper differently (seq x seq score matmuls vs 1-row KV-cache reads).
"""
import argparse

from repro.core import SearchConfig, describe, dram_pim, optimize_network
from repro.workloads import list_scenarios, parse_scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="deepseek_moe_16b_smoke:prefill@64",
                    help="zoo scenario (arch[:phase][@length][xblocks]); "
                         "see `run.py workloads` for the full list")
    ap.add_argument("--candidates", type=int, default=4)
    ap.add_argument("--max-steps", type=int, default=512)
    args = ap.parse_args()

    arch = dram_pim(channels_per_layer=2, banks_per_channel=4,
                    columns_per_bank=1024)
    sc = parse_scenario(args.scenario)
    cfg = SearchConfig(n_candidates=args.candidates, seed=0,
                       max_steps=args.max_steps, mode="transform")

    print(f"zoo scenarios: {len(list_scenarios())} full + "
          f"{len(list_scenarios(smoke=True))} smoke "
          f"(this run: {sc.name})")

    for phase in ("prefill", "decode"):
        name = f"{sc.arch_id}{'_smoke' if sc.smoke else ''}:{phase}"
        desc = describe(name)
        macs = sum(l.macs for l in desc.layers)
        print(f"\n{desc.name}: {len(desc.layers)} layers, "
              f"{len(desc.edges)} edges, {macs / 1e6:.1f} MMACs")
        for l in desc.layers[:6]:
            print(f"  {l.name:28s} K={l.K:5d} C={l.C:5d} "
                  f"P={l.P:5d} Q={l.Q:3d} N={l.N}")
        if len(desc.layers) > 6:
            print(f"  ... {len(desc.layers) - 6} more")
        res = optimize_network(desc.layers, desc.edges, arch, cfg)
        print(f"  transform search: {res.total_ns / 1e6:.3f} ms on "
              f"{arch.name}")


if __name__ == "__main__":
    main()
