"""Scenario: use the Fast-OverlaPIM mapper to derive an overlap schedule,
then execute it as pipeline parallelism on a JAX device mesh.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/map_and_pipeline.py

This is the DESIGN.md Section 3 level-2 adaptation end-to-end: the
paper's transformation orders microbatch tiles by input-ready time; the
wavefront pipeline executes them across mesh stages.
"""
import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro.pipeline.overlap_pipeline import (           # noqa: E402
    overlap_schedule, pipeline_forward, sequential_reference)


def main():
    n_stages = len(jax.devices())
    mesh = jax.make_mesh((n_stages,), ("stage",))
    d, n_micro = 64, 8

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    k = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(k, (n_stages, d, d)) * (1.0 / d ** 0.5),
        "b": jnp.zeros((n_stages, d)),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, 16, d))

    # microbatch ready times (e.g. streamed request arrival) -> the
    # paper's transformation gives the emission order
    ready = np.array([3.0, 0.0, 5.0, 1.0, 7.0, 2.0, 6.0, 4.0])
    order = overlap_schedule(ready)
    print(f"stages={n_stages} microbatches={n_micro}")
    print(f"ready times: {ready.tolist()}")
    print(f"overlap-transformed emission order: {order.tolist()}")

    y = pipeline_forward(stage_fn, params, x, mesh, axis="stage",
                         order=order)
    y_ref = sequential_reference(stage_fn, params, x)
    err = float(jnp.abs(y - y_ref).max())
    print(f"pipeline output matches sequential reference: "
          f"max_err={err:.2e}")
    ticks_pipe = n_micro + n_stages - 1
    ticks_seq = n_micro * n_stages
    print(f"wavefront ticks {ticks_pipe} vs sequential {ticks_seq} "
          f"(= {ticks_seq / ticks_pipe:.1f}x overlap speedup at equal "
          f"stage latency)")


if __name__ == "__main__":
    main()
