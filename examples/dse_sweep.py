"""Quickstart: co-search PIM architecture x overlap mapping (DSE).

    PYTHONPATH=src python examples/dse_sweep.py [--budget 12]
    PYTHONPATH=src python examples/dse_sweep.py --objective edp

Sweeps a small grid of ``dram_pim`` variants for resnet18, scoring each
architecture point with the full overlap-driven mapping search (batched
engine, one shared instance across all points), and prints the
latency/energy/area Pareto frontier plus the iso-area winner against the
paper's default 2-channel x 8-bank configuration. ``--objective`` makes
the per-point mapping search energy-aware (``energy`` / ``edp`` /
``blend`` — see DESIGN.md Section 9); the frontier then trades
mapping-level energy, including the movement energy of
transform-relocated tiles, not just the arch-level proxies. Pass
``--journal`` to make the sweep resumable (re-running serves every point
from the journal and performs zero new mapping searches).
"""
import argparse

from repro.core import OBJECTIVES
from repro.dse import (DSEConfig, ParamSpace, frontier_table, record_edp,
                       run_dse, summarize)


def small_dram_space() -> ParamSpace:
    """A restricted dram_pim space so the quickstart finishes in ~10 s:
    channel/bank/column allocation only, default point = ``dram_pim()``."""
    return ParamSpace(
        family="dram_pim",
        axes={
            "channels_per_layer": (1, 2, 4),
            "banks_per_channel": (4, 8, 16),
            "columns_per_bank": (4096, 8192),
        },
        constraints=[
            lambda p: (p["channels_per_layer"] * p["banks_per_channel"]
                       <= 32),
        ],
        defaults={"channels_per_layer": 2, "banks_per_channel": 8,
                  "columns_per_bank": 8192},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=12,
                    help="design points to score")
    ap.add_argument("--candidates", type=int, default=6,
                    help="mapping candidates per layer per point")
    ap.add_argument("--objective", default="edp", choices=OBJECTIVES,
                    help="mapping-search objective (default: edp — the "
                         "energy-aware search the frontier is built on)")
    ap.add_argument("--journal", default=None,
                    help="JSONL journal path (makes the sweep resumable)")
    args = ap.parse_args()

    space = small_dram_space()
    cfg = DSEConfig(network="resnet18", mode="transform", explorer="grid",
                    budget=args.budget, n_candidates=args.candidates,
                    max_steps=1024, objective=args.objective,
                    journal_path=args.journal)
    print(f"grid sweep: {space.family} x resnet18, "
          f"budget={cfg.budget} of {space.size} grid points, "
          f"objective={cfg.objective}")
    res = run_dse(cfg, space=space)

    print(summarize(res))
    print("\nPareto frontier (latency / energy / area, all minimized):")
    print(frontier_table(res.frontier))

    best = res.best_within_area()
    if best is not None and best["total_ns"] < res.baseline["total_ns"]:
        print(f"\nAt the default config's area budget, the best variant "
              f"is {res.baseline['total_ns'] / best['total_ns']:.2f}x "
              f"faster — architecture search pays even before touching "
              f"the mapper.")
    base_edp = record_edp(res.baseline)
    best_edp = res.best_by("edp_ns_pj")
    if best_edp is not None:
        edp = record_edp(best_edp)
        if edp < base_edp:
            print(f"Best EDP point beats the default config by "
                  f"{base_edp / edp:.2f}x on energy-delay product "
                  f"({best_edp['arch_name']}).")


if __name__ == "__main__":
    main()
