"""Serving example: batched generation with KV cache through the Engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2_780m]

Uses the reduced smoke config of the chosen architecture (random
weights — this demonstrates the serving path: prefill -> primed cache ->
jitted single-token decode across a request batch).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import model_zoo
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, scfg=ServeConfig(
        max_seq=args.prompt_len + args.new_tokens + 1,
        max_new_tokens=args.new_tokens,
        temperature=args.temperature))

    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab,
                          (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    print(f"arch={args.arch} (smoke config, family={cfg.family})")
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. "
          f"compile)")
    for i, row in enumerate(out):
        print(f"  seq{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
