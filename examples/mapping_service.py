"""Quickstart: mapping-as-a-service (deployment-time DSE).

    PYTHONPATH=src python examples/mapping_service.py

Stands up a local ``MappingService``, answers one deployment request —
"best dram_pim (arch, mapping) pair for resnet18" — then demonstrates
the three serving layers that make repeat traffic cheap:

1. an exact repeat is answered from the response memo (no sweep),
2. a fresh service on the same journal (a restart) replays every point
   from the content-keyed journal with **zero new mapping searches**
   and a byte-identical frontier,
3. a deadline-bounded request returns the best-so-far frontier.

The journal lives in a temp dir so the example is self-contained;
point ``MappingService(journal_path=...)`` somewhere persistent for a
real deployment. The CLI equivalent is ``python benchmarks/run.py
serve-dse`` (see README.md). DESIGN.md Section 11 has the contract.
"""
import os
import tempfile

from repro.serve import MappingRequest, MappingService


def main():
    tmp = tempfile.mkdtemp(prefix="mapping_service_")
    journal = os.path.join(tmp, "service.jsonl")
    req = MappingRequest(network="resnet18", family="dram_pim",
                         explorer="grid", budget=8, n_candidates=4,
                         max_steps=1024)

    print(f"request: network={req.network} family={req.family} "
          f"budget={req.budget} (key {req.cache_key()[:12]})")

    svc = MappingService(journal_path=journal)
    try:
        cold = svc.request(req)
        print(f"cold:    served_from={cold.served_from} "
              f"evaluated={cold.evaluated} wall_s={cold.wall_s:.1f}")
        print(f"         best={cold.best['arch_name']} "
              f"latency_ms={cold.best['total_ns'] / 1e6:.3f} "
              f"area_mm2={cold.best['area_mm2']:.2f} "
              f"(frontier of {len(cold.frontier_points)})")

        memo = svc.request(req)
        print(f"repeat:  served_from={memo.served_from} — no sweep ran, "
              f"the stored response was replayed "
              f"(sweeps={svc.stats['sweeps']})")
    finally:
        svc.close()

    # "restart": a brand-new service over the same journal file
    svc = MappingService(journal_path=journal)
    try:
        warm = svc.request(req)
        print(f"restart: served_from={warm.served_from} "
              f"evaluated={warm.evaluated} "
              f"from_journal={warm.from_journal} — zero new searches")
        assert warm.evaluated == 0
        assert warm.frontier_json == cold.frontier_json
        print("         frontier byte-identical to the cold run")

        rush = svc.request(MappingRequest(
            network=req.network, family=req.family, explorer="grid",
            budget=64, n_candidates=4, max_steps=1024, deadline_s=2.0))
        print(f"rush:    budget=64 deadline_s=2.0 -> "
              f"proposed={rush.proposed} deadline_hit={rush.deadline_hit} "
              f"best={rush.best['arch_name']} (best-so-far answer)")
    finally:
        svc.close()


if __name__ == "__main__":
    main()
