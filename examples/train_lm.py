"""End-to-end training driver: train a small LM on the synthetic Markov
stream with the full substrate (sharded step, AdamW, checkpointing,
auto-resume).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300 \
        --ckpt /tmp/ckpt_100m     # the ~100M-param configuration

The 100M config is the deliverable target; on this CPU container it runs
at a few seconds/step — the default 'tiny' config demonstrates the same
loss curve in ~2 minutes. Interrupting and re-running with the same
--ckpt resumes from the newest checkpoint.
"""
import argparse
import logging

from repro.configs import get_config
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.models.common import ModelConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig

SIZES = {
    # ~1M params: CI-fast demonstration
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                 d_ff=512, vocab=512),
    # ~25M params
    "25m": dict(n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
                d_ff=2048, vocab=2048),
    # ~100M params (the deliverable-scale config)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                 d_ff=3072, vocab=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=sorted(SIZES))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(message)s")
    cfg = ModelConfig(arch_id=f"train_lm_{args.size}", family="dense",
                      **SIZES[args.size])
    mesh = make_host_mesh(model=1)
    trainer = Trainer(
        cfg, mesh,
        opt_cfg=OptimizerConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps),
        tcfg=TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt,
                           ckpt_every=50, log_every=10),
        dcfg=DataConfig(batch=args.batch, seq=args.seq))
    last = trainer.run()
    first = trainer.metrics_history[0]
    print(f"\nfirst logged loss: {first['loss']:.4f}  ->  "
          f"final loss: {last['loss']:.4f}")


if __name__ == "__main__":
    main()
