"""Quickstart: optimize a DNN's PIM mapping with Fast-OverlaPIM.

    PYTHONPATH=src python examples/quickstart.py [--net resnet18]

Runs the three optimization modes of the paper on a reduced PIM config
and prints the per-mode latency plus the best transformed mapping of the
busiest layer.
"""
import argparse

from repro.core import (SearchConfig, describe, dram_pim,
                        optimize_network)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="resnet18",
                    choices=["resnet18", "vgg16", "resnet50",
                             "bert_encoder"])
    ap.add_argument("--candidates", type=int, default=16)
    args = ap.parse_args()

    arch = dram_pim(channels_per_layer=2, banks_per_channel=4,
                    columns_per_bank=2048)
    desc = describe(args.net)
    print(f"network: {args.net} ({len(desc.layers)} layers), "
          f"arch: {arch.name} ({arch.n_target_instances} banks)")

    results = {}
    for mode in ("original", "overlap", "transform"):
        cfg = SearchConfig(n_candidates=args.candidates, seed=0,
                           max_steps=4096, mode=mode)
        res = optimize_network(desc.layers, desc.edges, arch, cfg)
        results[mode] = res
        print(f"  {mode:10s}: {res.total_ns / 1e6:8.2f} ms")

    sp = results["original"].total_ns / results["transform"].total_ns
    print(f"\nBest Transform speedup over Best Original: {sp:.2f}x")

    busiest = max(range(len(desc.layers)),
                  key=lambda i: desc.layers[i].macs)
    lr = results["transform"].layers[busiest]
    print(f"\nbusiest layer {desc.layers[busiest].name} "
          f"(transformed={lr.transformed}, "
          f"moved_frac={lr.moved_frac:.2f}):")
    print(lr.mapping.pretty())


if __name__ == "__main__":
    main()
