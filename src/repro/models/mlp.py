"""Dense FFN (SwiGLU / GELU) and MoE (GShard-style dense dispatch)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, split_keys


def init_mlp(cfg: ModelConfig, key, d_model=None, d_ff=None,
             dtype=jnp.float32) -> Dict:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.mlp == "swiglu":
        ks = split_keys(key, ["w1", "w3", "w2"])
        return {"w1": dense_init(ks["w1"], d, f, dtype),
                "w3": dense_init(ks["w3"], d, f, dtype),
                "w2": dense_init(ks["w2"], f, d, dtype)}
    ks = split_keys(key, ["w1", "w2"])
    return {"w1": dense_init(ks["w1"], d, f, dtype),
            "w2": dense_init(ks["w2"], f, d, dtype)}


def mlp(cfg: ModelConfig, params: Dict, x):
    from jax.ad_checkpoint import checkpoint_name
    if "w3" in params:
        h = jax.nn.silu(x @ params["w1"].astype(x.dtype)) * \
            (x @ params["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ params["w1"].astype(x.dtype))
    # named for the "mlp" remat policy only — an unconditional
    # checkpoint_name degrades the default full-remat scan (observed 7x
    # worse terms on olmo train; see EXPERIMENTS.md Perf C3)
    if cfg.remat_policy == "mlp":
        h = checkpoint_name(h, "mlp_hidden")
    return h @ params["w2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE: top-k routing, capacity-bounded einsum dispatch (GShard formulation).
# Experts shard on the "model" mesh axis (expert parallelism); the dispatch
# einsums lower to all-to-all-free sharded matmuls on the dry-run mesh.
# ---------------------------------------------------------------------------

def init_moe(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = split_keys(key, ["router", "w1", "w3", "w2", "sh"])
    std = 1.0 / (d ** 0.5)
    p = {
        "router": dense_init(ks["router"], d, e, jnp.float32),
        "w1": (jax.random.normal(ks["w1"], (e, d, f), jnp.float32)
               * std).astype(dtype),
        "w3": (jax.random.normal(ks["w3"], (e, d, f), jnp.float32)
               * std).astype(dtype),
        "w2": (jax.random.normal(ks["w2"], (e, f, d), jnp.float32)
               / (f ** 0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.d_ff
        p["shared"] = init_mlp(cfg, ks["sh"], d_model=d, d_ff=fs,
                               dtype=dtype)
    return p


def moe(cfg: ModelConfig, params: Dict, x) -> Tuple[jnp.ndarray,
                                                    jnp.ndarray]:
    """Dispatch by cfg.moe_impl: "gather" (production) or "einsum"."""
    if cfg.moe_impl == "gather":
        return moe_gather(cfg, params, x)
    return moe_einsum(cfg, params, x)


def _route(cfg: ModelConfig, params, xt):
    """Shared router: returns (probs, gate_vals, gate_idx, pos, keep, cap).

    Shard-local routing: tokens are viewed as [n_shards, T_local] (the
    leading axis aligns with the batch/data sharding), so position-in-
    expert cumsums stay device-local and capacity scales with LOCAL
    tokens."""
    ns, tl, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = (xt.astype(jnp.float32) @ params["router"])    # [ns, tl, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # [ns, tl, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    cap = int(tl * k / e * cfg.capacity_factor)
    cap = max(cap, min(k, tl))
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)   # [ns,tl,k,E]
    flat = onehot.reshape(ns, tl * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                   # [ns,tl*k,E]
    pos = (pos * flat).sum(-1).reshape(ns, tl, k)           # [ns,tl,k]
    keep = pos < cap
    return probs, gate_vals * keep, gate_idx, pos, keep, cap, onehot


def _aux_loss(cfg, probs, onehot):
    me = probs.mean(axis=(0, 1))
    ce = onehot.sum(axis=2).astype(jnp.float32).mean(axis=(0, 1))
    return (me * ce).sum() * cfg.n_experts * cfg.router_aux_coef


def _experts(cfg, params, xe, dtype):
    """xe [ns, E, cap, D] -> [ns, E, cap, D] through per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("secd,edf->secf", xe,
                               params["w1"].astype(dtype)))
    h = h * jnp.einsum("secd,edf->secf", xe, params["w3"].astype(dtype))
    return jnp.einsum("secf,efd->secd", h, params["w2"].astype(dtype))


def moe_gather(cfg: ModelConfig, params: Dict, x):
    """Sort/gather dispatch: tokens are copied into their expert slot by
    a gather (O(tokens) traffic, no dispatch FLOPs); results are gathered
    back per (token, choice) and gate-combined. The data->expert reshard
    happens in the expert einsum (all-to-all under SPMD)."""
    b, s_len, d = x.shape
    t = b * s_len
    ns = cfg.moe_shards if t % cfg.moe_shards == 0 else 1
    tl = t // ns
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(ns, tl, d)
    probs, gates, gate_idx, pos, keep, cap, onehot = _route(
        cfg, params, xt)

    # slot table [ns, E*cap] <- token index (tl = "dropped" sentinel)
    slot = jnp.full((ns, e * cap), tl, jnp.int32)
    flat_slot = gate_idx * cap + pos                        # [ns, tl, k]
    tok_ids = jnp.broadcast_to(jnp.arange(tl, dtype=jnp.int32)[None, :,
                                                              None],
                               (ns, tl, k))
    # dropped assignments write out-of-range -> mode="drop" discards them
    slot = slot.at[
        jnp.arange(ns, dtype=jnp.int32)[:, None, None],
        jnp.where(keep, flat_slot, e * cap)
    ].set(tok_ids, mode="drop")
    # guard: sentinel row appended so dropped tokens read zeros
    xt_pad = jnp.concatenate(
        [xt, jnp.zeros((ns, 1, d), xt.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xt_pad, slot[:, :, None].astype(jnp.int32), axis=1)
    xe = xe.reshape(ns, e, cap, d)
    if cfg.moe_expert_axis and ns > 1:
        axes = tuple(cfg.moe_data_axes) or (None,)
        xe = jax.lax.with_sharding_constraint(
            xe, jax.sharding.PartitionSpec(
                axes if len(axes) > 1 else axes[0],
                cfg.moe_expert_axis, None, None))

    ye = _experts(cfg, params, xe, x.dtype)                 # [ns,E,cap,D]

    # combine: reshard expert outputs back to data-parallel (one
    # all-to-all), then a shard-LOCAL back-gather per (token, choice).
    # (A scatter-add-in-slot-space combine was tried — psum of y instead
    # of the yef reshard — but its transpose gathers from a model-sharded
    # source and cost +50% collective bytes; see EXPERIMENTS.md Perf.)
    yef = ye.reshape(ns, e * cap, d)
    if cfg.moe_data_axes and ns > 1:
        axes = tuple(cfg.moe_data_axes)
        spec = jax.sharding.PartitionSpec(
            axes if len(axes) > 1 else axes[0], None, None)
        yef = jax.lax.with_sharding_constraint(yef, spec)
    back = jnp.take_along_axis(
        yef, jnp.where(keep, flat_slot, 0).reshape(ns, tl * k)[:, :,
                                                               None],
        axis=1).reshape(ns, tl, k, d)
    y = (back.astype(jnp.float32)
         * gates.astype(jnp.float32)[..., None]).sum(axis=2)
    y = y.astype(x.dtype)

    if "shared" in params:
        y = y + mlp(cfg, params["shared"], xt)
    return y.reshape(b, s_len, d), _aux_loss(cfg, probs, onehot)


def moe_einsum(cfg: ModelConfig, params: Dict, x) -> Tuple[jnp.ndarray,
                                                           jnp.ndarray]:
    """GShard one-hot einsum dispatch (reference implementation)."""
    b, s_len, d = x.shape
    t = b * s_len
    e, k = cfg.n_experts, cfg.top_k
    ns = cfg.moe_shards if t % cfg.moe_shards == 0 else 1
    tl = t // ns
    xt = x.reshape(ns, tl, d)
    logits = (xt.astype(jnp.float32) @ params["router"])    # [ns, tl, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # [ns, tl, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(tl * k / e * cfg.capacity_factor)
    cap = max(cap, min(k, tl))
    # position of each (token, choice) within its expert queue (LOCAL)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)   # [ns,tl,k,E]
    flat = onehot.reshape(ns, tl * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                   # [ns,tl*k,E]
    pos = (pos * flat).sum(-1).reshape(ns, tl, k)           # [ns,tl,k]
    keep = pos < cap
    gate_vals = gate_vals * keep

    # dispatch/combine tensors [ns, tl, E, cap]
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                            dtype=x.dtype)                  # [ns,tl,k,cap]
    disp = jnp.einsum("stke,stkc->stec", onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum("stke,stkc,stk->stec",
                      onehot.astype(jnp.float32),
                      pos_oh.astype(jnp.float32),
                      gate_vals.astype(jnp.float32)).astype(x.dtype)

    xe = jnp.einsum("stec,std->secd", disp, xt)             # [ns,E,cap,D]
    h = jax.nn.silu(jnp.einsum("secd,edf->secf", xe,
                               params["w1"].astype(x.dtype)))
    h = h * jnp.einsum("secd,edf->secf", xe,
                       params["w3"].astype(x.dtype))
    ye = jnp.einsum("secf,efd->secd", h, params["w2"].astype(x.dtype))
    y = jnp.einsum("stec,secd->std", comb, ye)

    if "shared" in params:
        y = y + mlp(cfg, params["shared"], xt)

    # GShard load-balancing aux loss
    me = probs.mean(axis=(0, 1))                            # [E]
    ce = onehot.sum(axis=2).astype(jnp.float32).mean(axis=(0, 1))
    aux = (me * ce).sum() * e * cfg.router_aux_coef
    return y.reshape(b, s_len, d), aux
