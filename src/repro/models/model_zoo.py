"""Family dispatch: one API across all 10 architectures.

``audio`` (encoder-decoder) dispatches to ``encdec``; everything else to
``lm``. All functions are pure and jit-friendly.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax.numpy as jnp

from . import encdec, lm
from .common import ModelConfig

PyTree = Any


def init_params(cfg: ModelConfig, key) -> PyTree:
    if cfg.family == "audio":
        return encdec.init_params(cfg, key)
    return lm.init_params(cfg, key)


def param_shapes(cfg: ModelConfig) -> PyTree:
    if cfg.family == "audio":
        return encdec.param_shapes(cfg)
    return lm.param_shapes(cfg)


def loss_fn(cfg: ModelConfig, params: PyTree, batch: Dict):
    if cfg.family == "audio":
        return encdec.loss_fn(cfg, params, batch)
    return lm.loss_fn(cfg, params, batch)


def forward(cfg: ModelConfig, params: PyTree, batch: Dict):
    if cfg.family == "audio":
        return encdec.forward(cfg, params, batch["tokens"],
                              batch["frames"])
    return lm.forward(cfg, params, batch["tokens"],
                      batch.get("extra_embeds"))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    if cfg.family == "audio":
        return encdec.init_cache(cfg, batch, max_seq, cfg.enc_frames)
    return lm.init_cache(cfg, batch, max_seq)


def decode_step(cfg: ModelConfig, params: PyTree, cache: PyTree, tokens):
    if cfg.family == "audio":
        return encdec.decode_step(cfg, params, cache, tokens)
    return lm.decode_step(cfg, params, cache, tokens)


def prefill(cfg: ModelConfig, params: PyTree, tokens, max_seq: int,
            frames=None):
    if cfg.family == "audio":
        cache = encdec.init_cache(cfg, tokens.shape[0], max_seq,
                                  cfg.enc_frames)
        cache = encdec.prime_cross_cache(cfg, params, cache, frames)
        # teacher-force the prompt through decode steps is wasteful; run
        # forward once and only keep the cache of self-attn prefill
        logits, _ = encdec.forward(cfg, params, tokens, frames)
        return logits[:, -1, :], cache
    return lm.prefill(cfg, params, tokens, max_seq)
