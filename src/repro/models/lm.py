"""Decoder-only language models (dense / moe / ssm / hybrid / vlm).

Per-layer parameters are stacked on a leading layer axis and applied with
``jax.lax.scan``. The hybrid (Zamba-2) family adds ONE shared attention
block (shared weights) applied every ``attn_every`` layers via
``lax.cond`` inside the scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention, decode_attention, init_attn,
                        init_kv_cache, prefill_into_cache)
from .common import (ModelConfig, apply_norm, cast_tree, dense_init,
                     split_keys)
from .mlp import init_mlp, init_moe, mlp, moe
from .ssm import init_mamba2, init_ssm_cache, mamba2_block, mamba2_decode

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key, dtype) -> Dict:
    ks = split_keys(key, ["attn", "ffn"])
    p: Dict = {}
    if cfg.family in ("dense", "moe", "vlm"):
        p["attn_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["attn"] = init_attn(cfg, ks["attn"], dtype=dtype)
        p["ffn_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        if cfg.family == "moe":
            p["moe"] = init_moe(cfg, ks["ffn"], dtype=dtype)
        else:
            p["mlp"] = init_mlp(cfg, ks["ffn"], dtype=dtype)
    elif cfg.family in ("ssm", "hybrid"):
        p["ssm_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ssm"] = init_mamba2(cfg, ks["attn"], dtype=dtype)
    else:
        raise ValueError(cfg.family)
    return p


def init_params(cfg: ModelConfig, key) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, ["embed", "unembed", "layers", "shared"])
    layer_keys = jax.random.split(ks["layers"], cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(cfg, k, dtype))(layer_keys)
    params = {
        "embed": dense_init(ks["embed"], cfg.padded_vocab, cfg.d_model,
                            dtype, scale=1.0),
        "unembed": dense_init(ks["unembed"], cfg.d_model,
                              cfg.padded_vocab, dtype),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": layers,
    }
    if cfg.family == "hybrid" and cfg.attn_every:
        sk = split_keys(ks["shared"], ["attn", "mlp"])
        params["shared_attn"] = {
            "norm": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attn(cfg, sk["attn"], dtype=dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": init_mlp(cfg, sk["mlp"], dtype=dtype),
        }
    return params


def param_shapes(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct tree without allocating (dry-run input)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# Forward (training / scoring)
# ---------------------------------------------------------------------------

def _vocab_mask(cfg: ModelConfig, logits):
    if cfg.padded_vocab == cfg.vocab:
        return logits
    mask = (jnp.arange(cfg.padded_vocab) < cfg.vocab)
    return jnp.where(mask[None, None, :], logits, -1e9)


def _shared_attn_apply(cfg, shared, x):
    h = apply_norm(cfg, x, shared["norm"])
    x = x + attention(cfg, shared["attn"], h, causal=True)
    h = apply_norm(cfg, x, shared["mlp_norm"])
    return x + mlp(cfg, shared["mlp"], h)


def forward(cfg: ModelConfig, params: PyTree, tokens,
            extra_embeds=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B,S] -> (logits [B,S,Vp], aux_loss scalar).

    ``extra_embeds`` [B,S_img,D] (vlm/audio stub frontends) is prepended;
    its positions are dropped from the returned logits."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[tokens]
    n_extra = 0
    if extra_embeds is not None:
        n_extra = extra_embeds.shape[1]
        x = jnp.concatenate([extra_embeds.astype(cdt), x], axis=1)

    shared = params.get("shared_attn")

    def body(carry, inp):
        x, aux = carry
        idx, lp = inp
        if cfg.family in ("ssm", "hybrid"):
            h = apply_norm(cfg, x, lp["ssm_norm"])
            x = x + mamba2_block(cfg, lp["ssm"], h)
            if shared is not None and cfg.attn_every:
                x = jax.lax.cond(
                    (idx % cfg.attn_every) == cfg.attn_every - 1,
                    lambda v: _shared_attn_apply(cfg, shared, v),
                    lambda v: v, x)
        else:
            h = apply_norm(cfg, x, lp["attn_norm"])
            x = x + attention(cfg, lp["attn"], h, causal=True)
            h = apply_norm(cfg, x, lp["ffn_norm"])
            if cfg.family == "moe":
                y, a = moe(cfg, lp["moe"], h)
                x = x + y
                aux = aux + a
            else:
                x = x + mlp(cfg, lp["mlp"], h)
        return (x, aux), None

    # remat: back-prop recomputes inside each layer; only layer inputs are
    # saved — required to fit train_4k activations in HBM at 4k x 16/device.
    # "dots" policy additionally saves matmul outputs (skips recompute of
    # MXU work when HBM headroom exists).
    if cfg.remat_policy == "dots":
        ck = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat_policy == "mlp":
        ck = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.save_only_these_names(
                "mlp_hidden"))
    else:
        ck = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(
        ck, (x, jnp.zeros((), jnp.float32)),
        (jnp.arange(cfg.n_layers), params["layers"]))
    x = apply_norm(cfg, x, params["final_norm"])
    logits = x @ params["unembed"].astype(cdt)
    logits = _vocab_mask(cfg, logits)
    if n_extra:
        logits = logits[:, n_extra:, :]
    return logits, aux


def loss_fn(cfg: ModelConfig, params: PyTree, batch: Dict) -> Tuple:
    """Next-token cross entropy. batch: tokens [B,S], labels [B,S]."""
    logits, aux = forward(cfg, params, batch["tokens"],
                          batch.get("extra_embeds"))
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                               axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(gold)
    ce = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# KV/SSM caches + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    cdt = jnp.dtype(cfg.compute_dtype)
    L = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        kv = jax.vmap(lambda _: init_kv_cache(
            batch, max_seq, cfg.n_kv_heads, cfg.hd, cdt))(jnp.arange(L))
        return {"layers": kv, "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        ssm = jax.vmap(lambda _: init_ssm_cache(cfg, batch, cdt))(
            jnp.arange(L))
        return {"layers": ssm, "pos": jnp.zeros((), jnp.int32)}
    # hybrid: ssm cache per layer + shared-attn KV with ONE slot per
    # shared-block invocation (L/attn_every), not per layer — 6x less
    # cache at zamba2's attn_every=6
    ssm = jax.vmap(lambda _: init_ssm_cache(cfg, batch, cdt))(
        jnp.arange(L))
    n_slots = max(1, (L + cfg.attn_every - 1) // cfg.attn_every)
    kv = jax.vmap(lambda _: init_kv_cache(
        batch, max_seq, cfg.n_kv_heads, cfg.hd, cdt))(jnp.arange(n_slots))
    return {"layers": ssm, "attn": kv,
            "pos": jnp.zeros((), jnp.int32)}


def decode_step(cfg: ModelConfig, params: PyTree, cache: PyTree,
                tokens) -> Tuple[jnp.ndarray, PyTree]:
    """tokens [B] -> (logits [B,Vp], new cache). One token for the whole
    batch (the serving engine batches requests at this granularity)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    pos = cache["pos"]
    x = params["embed"].astype(cdt)[tokens][:, None, :]
    shared = params.get("shared_attn")

    def _slot_get(kv_all, slot):
        return jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, slot, 0,
                                                   keepdims=False),
            kv_all)

    def _slot_set(kv_all, slot, kv_one):
        return jax.tree_util.tree_map(
            lambda a, b: jax.lax.dynamic_update_index_in_dim(
                a, b.astype(a.dtype), slot, 0), kv_all, kv_one)

    def body(carry, inp):
        x, kv_all = carry
        idx, lp, lc = inp
        if cfg.family in ("ssm", "hybrid"):
            h = apply_norm(cfg, x, lp["ssm_norm"])
            y, sc = mamba2_decode(cfg, lp["ssm"], h, lc)
            x = x + y
            if cfg.family == "hybrid" and shared is not None:
                def do_attn(args):
                    v, kva = args
                    slot = idx // cfg.attn_every
                    ac = _slot_get(kva, slot)
                    hh = apply_norm(cfg, v, shared["norm"])
                    yy, ac = decode_attention(cfg, shared["attn"], hh,
                                              ac, pos)
                    v = v + yy
                    hh = apply_norm(cfg, v, shared["mlp_norm"])
                    return (v + mlp(cfg, shared["mlp"], hh),
                            _slot_set(kva, slot, ac))
                x, kv_all = jax.lax.cond(
                    (idx % cfg.attn_every) == cfg.attn_every - 1,
                    do_attn, lambda a: a, (x, kv_all))
            return (x, kv_all), sc
        h = apply_norm(cfg, x, lp["attn_norm"])
        y, lc = decode_attention(cfg, lp["attn"], h, lc, pos)
        x = x + y
        h = apply_norm(cfg, x, lp["ffn_norm"])
        if cfg.family == "moe":
            y, _ = moe(cfg, lp["moe"], h)
            x = x + y
        else:
            x = x + mlp(cfg, lp["mlp"], h)
        return (x, kv_all), lc

    kv0 = cache.get("attn", jnp.zeros((), cdt))
    (x, kv_new), new_layers = jax.lax.scan(
        body, (x, kv0),
        (jnp.arange(cfg.n_layers), params["layers"], cache["layers"]))
    x = apply_norm(cfg, x, params["final_norm"])
    logits = x[:, 0, :] @ params["unembed"].astype(cdt)
    logits = _vocab_mask(cfg, logits[:, None, :])[:, 0, :]
    out = {"layers": new_layers, "pos": pos + 1}
    if "attn" in cache:
        out["attn"] = kv_new
    return logits, out


def prefill(cfg: ModelConfig, params: PyTree, tokens,
            max_seq: int) -> Tuple[jnp.ndarray, PyTree]:
    """Prefill a prompt into a fresh cache; returns (last logits, cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_seq)
    x = params["embed"].astype(cdt)[tokens]
    shared = params.get("shared_attn")

    def _slot_set(kv_all, slot, kv_one):
        return jax.tree_util.tree_map(
            lambda a, v: jax.lax.dynamic_update_index_in_dim(
                a, v.astype(a.dtype), slot, 0), kv_all, kv_one)

    def body(carry, inp):
        x, kv_all = carry
        idx, lp, lc = inp
        if cfg.family in ("ssm", "hybrid"):
            h = apply_norm(cfg, x, lp["ssm_norm"])
            # run block and also refresh the decode cache (state + conv)
            y = mamba2_block(cfg, lp["ssm"], h)
            sc = _ssm_cache_from_prefill(cfg, lp["ssm"], h, lc)
            x = x + y
            if cfg.family == "hybrid" and shared is not None:
                def do_attn(args):
                    v, kva = args
                    slot = idx // cfg.attn_every
                    ac = jax.tree_util.tree_map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, slot, 0, keepdims=False), kva)
                    hn = apply_norm(cfg, v, shared["norm"])
                    yy, ac = prefill_into_cache(cfg, shared["attn"],
                                                hn, ac)
                    v = v + yy
                    hn = apply_norm(cfg, v, shared["mlp_norm"])
                    return (v + mlp(cfg, shared["mlp"], hn),
                            _slot_set(kva, slot, ac))
                x, kv_all = jax.lax.cond(
                    (idx % cfg.attn_every) == cfg.attn_every - 1,
                    do_attn, lambda a: a, (x, kv_all))
            return (x, kv_all), sc
        h = apply_norm(cfg, x, lp["attn_norm"])
        y, lc = prefill_into_cache(cfg, lp["attn"], h, lc)
        x = x + y
        h = apply_norm(cfg, x, lp["ffn_norm"])
        if cfg.family == "moe":
            y, _ = moe(cfg, lp["moe"], h)
            x = x + y
        else:
            x = x + mlp(cfg, lp["mlp"], h)
        return (x, kv_all), lc

    cdt0 = cache.get("attn", jnp.zeros((), cdt))
    (x, kv_new), new_layers = jax.lax.scan(
        body, (x, cdt0),
        (jnp.arange(cfg.n_layers), params["layers"], cache["layers"]))
    x = apply_norm(cfg, x, params["final_norm"])
    logits = x[:, -1, :] @ params["unembed"].astype(cdt)
    logits = _vocab_mask(cfg, logits[:, None, :])[:, 0, :]
    out = {"layers": new_layers, "pos": jnp.asarray(s, jnp.int32)}
    if "attn" in cache:
        out["attn"] = kv_new
    return logits, out


def _ssm_cache_from_prefill(cfg: ModelConfig, lp: Dict, h, sc) -> Dict:
    """Recompute the decode-time SSM cache from a prefilled sequence: final
    SSD state + last (conv_width - 1) pre-activation conv inputs."""
    import jax.nn as jnn
    b, s, _ = h.shape
    hh, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    kw = cfg.ssm_conv
    xin = h @ lp["wx"].astype(h.dtype)
    Bv = h @ lp["wB"].astype(h.dtype)
    Cv = h @ lp["wC"].astype(h.dtype)
    dt = h @ lp["wdt"].astype(h.dtype)
    from .ssm import _causal_dw_conv, ssd_chunked
    xc = jnn.silu(_causal_dw_conv(xin, lp["conv_x"].astype(h.dtype)))
    Bc = jnn.silu(_causal_dw_conv(Bv, lp["conv_B"].astype(h.dtype)))
    Cc = jnn.silu(_causal_dw_conv(Cv, lp["conv_C"].astype(h.dtype)))
    dtp = jnn.softplus(dt.astype(jnp.float32) + lp["dt_bias"][None, None])
    A = -jnp.exp(lp["A_log"])
    _, final = ssd_chunked(xc.reshape(b, s, hh, p), dtp, A,
                           Bc.reshape(b, s, g, n), Cc.reshape(b, s, g, n),
                           chunk=min(cfg.ssm_chunk, s))
    def tail(v):
        return v[:, -(kw - 1):, :].astype(sc["conv_x"].dtype) \
            if s >= kw - 1 else jnp.pad(v, ((0, 0), (kw - 1 - s, 0),
                                            (0, 0))).astype(
                sc["conv_x"].dtype)
    return {"state": final, "conv_x": tail(xin), "conv_B": tail(Bv),
            "conv_C": tail(Cv)}
