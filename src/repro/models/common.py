"""Shared model substrate: configs, norms, RoPE, initializers.

All models store per-layer parameters STACKED on a leading layer axis and
apply blocks with ``jax.lax.scan`` — HLO stays compact (fast multi-pod
lowering, parseable collective schedule) and layer count is a free config
knob.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

VOCAB_PAD = 512  # pad vocab so the unembed shards on any model axis <= 512


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0
    norm: str = "rmsnorm"        # rmsnorm | layernorm_np (OLMo)
    mlp: str = "swiglu"          # swiglu | gelu
    rope_theta: float = 10_000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # routing/capacity is computed per data shard (set to the mesh's data
    # extent by the launcher; 1 on single-device tests). Without this the
    # global-view [T, E, cap] dispatch tensors scale with GLOBAL tokens
    # (observed 162 GiB/device on deepseek_moe_16b train_4k).
    moe_shards: int = 1
    # "gather": sort/gather dispatch, ~0 dispatch FLOPs (production);
    # "einsum": GShard one-hot einsum dispatch (reference + ablation —
    # costs ~2x the expert FLOPs at deepseek's top-6/64 shapes).
    moe_impl: str = "gather"
    # mesh axes the token-shard dim maps to; when set, the combine path
    # re-shards expert outputs back to data-parallel BEFORE the gather
    # (explicit all-to-all) — otherwise XLA lowers the cross-expert-shard
    # gather as a masked all-reduce and can defer the MoE psum all the
    # way to the fp32 logits (observed 3.4 GB/step all-reduce).
    moe_data_axes: tuple = ()
    # mesh axis the expert dim is sharded on; when set, the dispatched
    # activations are pinned to (data, expert) sharding so the data->
    # expert reshard is one all-to-all instead of an all-gather of the
    # full [E, cap, D] slot tensor.
    moe_expert_axis: str = ""
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (Zamba-2): one shared attention block applied every k layers
    attn_every: int = 0
    # encoder-decoder (Whisper backbone)
    enc_layers: int = 0
    enc_frames: int = 1500
    # vlm (LLaVA-NeXT backbone): anyres patch embeddings prepended (stub)
    img_tokens: int = 0
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # decoder learned-position table size (encoder-decoder family)
    max_seq: int = 32768
    # remat: "full" recomputes everything in backward (min memory);
    # "dots" saves matmul outputs (no recompute of MXU work — right when
    # HBM headroom exists, see EXPERIMENTS.md Perf olmo iteration 2)
    remat_policy: str = "full"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_ssm_family(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def params_count(self, params: PyTree) -> int:
        return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm_np(x, _scale_unused=None, eps=1e-5):
    """Non-parametric LayerNorm (OLMo: no scale/bias)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(cfg: ModelConfig, x, scale):
    if cfg.norm == "layernorm_np":
        return layernorm_np(x)
    return rmsnorm(x, scale)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, positions):
    """positions [*] -> (cos, sin) each [*, hd/2], float32."""
    hd = cfg.hd
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2,
                                               dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, hd]; cos/sin [S, hd/2] (broadcast over batch/heads)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype, scale: Optional[float] = None):
    std = scale if scale is not None else (1.0 / math.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * std).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def cast_tree(tree: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype,
                                                    jnp.floating) else x,
        tree)
