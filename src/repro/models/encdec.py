"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings [B, T_enc, D]. Encoder is
bidirectional; decoder layers are (causal self-attn, cross-attn, MLP).
Learned absolute positions (no RoPE).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention, decode_attention, gqa_decode_attend,
                        init_attn, init_kv_cache, prefill_into_cache)
from .common import ModelConfig, apply_norm, dense_init, split_keys
from .mlp import init_mlp, mlp

PyTree = Any


def _init_enc_layer(cfg, key, dtype):
    ks = split_keys(key, ["attn", "mlp"])
    return {
        "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": init_attn(cfg, ks["attn"], dtype=dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": init_mlp(cfg, ks["mlp"], dtype=dtype),
    }


def _init_dec_layer(cfg, key, dtype):
    ks = split_keys(key, ["self", "cross", "mlp"])
    return {
        "self_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "self_attn": init_attn(cfg, ks["self"], dtype=dtype),
        "cross_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "cross_attn": init_attn(cfg, ks["cross"], dtype=dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": init_mlp(cfg, ks["mlp"], dtype=dtype),
    }


def init_params(cfg: ModelConfig, key) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, ["embed", "unembed", "enc_pos", "dec_pos",
                          "enc", "dec"])
    enc_keys = jax.random.split(ks["enc"], cfg.enc_layers)
    dec_keys = jax.random.split(ks["dec"], cfg.n_layers)
    return {
        "embed": dense_init(ks["embed"], cfg.padded_vocab, cfg.d_model,
                            dtype, scale=1.0),
        "unembed": dense_init(ks["unembed"], cfg.d_model,
                              cfg.padded_vocab, dtype),
        "enc_pos": dense_init(ks["enc_pos"], cfg.enc_frames, cfg.d_model,
                              dtype, scale=0.02),
        "dec_pos": dense_init(ks["dec_pos"], cfg.max_seq, cfg.d_model,
                              dtype, scale=0.02),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "encoder": jax.vmap(lambda k: _init_enc_layer(cfg, k, dtype))(
            enc_keys),
        "decoder": jax.vmap(lambda k: _init_dec_layer(cfg, k, dtype))(
            dec_keys),
    }


def param_shapes(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def encode(cfg: ModelConfig, params: PyTree, frames) -> jnp.ndarray:
    """frames [B, T, D] (stub frontend output) -> encoder states."""
    cdt = jnp.dtype(cfg.compute_dtype)
    t = frames.shape[1]
    x = frames.astype(cdt) + params["enc_pos"].astype(cdt)[None, :t]

    def body(x, lp):
        h = apply_norm(cfg, x, lp["attn_norm"])
        x = x + attention(cfg, lp["attn"], h, causal=False)
        h = apply_norm(cfg, x, lp["ffn_norm"])
        return x + mlp(cfg, lp["mlp"], h), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"])
    return apply_norm(cfg, x, params["enc_norm"])


def forward(cfg: ModelConfig, params: PyTree, tokens,
            frames) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced decoding: (tokens [B,S], frames [B,T,D]) -> logits."""
    cdt = jnp.dtype(cfg.compute_dtype)
    enc = encode(cfg, params, frames)
    s = tokens.shape[1]
    x = params["embed"].astype(cdt)[tokens] \
        + params["dec_pos"].astype(cdt)[None, :s]

    def body(x, lp):
        h = apply_norm(cfg, x, lp["self_norm"])
        x = x + attention(cfg, lp["self_attn"], h, causal=True)
        h = apply_norm(cfg, x, lp["cross_norm"])
        x = x + attention(cfg, lp["cross_attn"], h, causal=False,
                          kv_x=enc)
        h = apply_norm(cfg, x, lp["ffn_norm"])
        return x + mlp(cfg, lp["mlp"], h), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["decoder"])
    x = apply_norm(cfg, x, params["final_norm"])
    logits = x @ params["unembed"].astype(cdt)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: PyTree, batch: Dict):
    logits, aux = forward(cfg, params, batch["tokens"], batch["frames"])
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                               axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce, {"ce": ce, "aux": aux}


# -- decode -----------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_T: int) -> PyTree:
    cdt = jnp.dtype(cfg.compute_dtype)
    L = cfg.n_layers
    self_kv = jax.vmap(lambda _: init_kv_cache(
        batch, max_seq, cfg.n_kv_heads, cfg.hd, cdt))(jnp.arange(L))
    cross_kv = jax.vmap(lambda _: init_kv_cache(
        batch, enc_T, cfg.n_kv_heads, cfg.hd, cdt))(jnp.arange(L))
    return {"self": self_kv, "cross": cross_kv,
            "pos": jnp.zeros((), jnp.int32)}


def prime_cross_cache(cfg: ModelConfig, params: PyTree, cache: PyTree,
                      frames) -> PyTree:
    """Precompute cross-attention K/V from the encoder output."""
    enc = encode(cfg, params, frames)
    b, t, _ = enc.shape
    kvh, hd = cfg.n_kv_heads, cfg.hd

    def per_layer(lp, _):
        k = (enc @ lp["cross_attn"]["wk"].astype(enc.dtype)
             ).reshape(b, t, kvh, hd)
        v = (enc @ lp["cross_attn"]["wv"].astype(enc.dtype)
             ).reshape(b, t, kvh, hd)
        return {"k": k.astype(enc.dtype), "v": v.astype(enc.dtype)}

    cross = jax.vmap(per_layer)(params["decoder"],
                                jnp.arange(cfg.n_layers))
    return {**cache, "cross": cross}


def decode_step(cfg: ModelConfig, params: PyTree, cache: PyTree,
                tokens) -> Tuple[jnp.ndarray, PyTree]:
    cdt = jnp.dtype(cfg.compute_dtype)
    pos = cache["pos"]
    x = params["embed"].astype(cdt)[tokens][:, None, :] \
        + jax.lax.dynamic_slice_in_dim(params["dec_pos"].astype(cdt),
                                       pos, 1, axis=0)[None]
    h_, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def body(x, inp):
        lp, sc, cc = inp
        h = apply_norm(cfg, x, lp["self_norm"])
        y, sc = decode_attention(cfg, lp["self_attn"], h, sc, pos,
                                 rope=False)
        x = x + y
        # cross attention against the primed cache (full enc length)
        h = apply_norm(cfg, x, lp["cross_norm"])
        b = x.shape[0]
        q = (h @ lp["cross_attn"]["wq"].astype(x.dtype)
             ).reshape(b, 1, h_, hd)
        enc_t = cc["k"].shape[1]
        y = gqa_decode_attend(q, cc["k"], cc["v"], enc_t - 1)
        y = y.astype(x.dtype) @ lp["cross_attn"]["wo"].astype(x.dtype)
        x = x + y
        h = apply_norm(cfg, x, lp["ffn_norm"])
        return x + mlp(cfg, lp["mlp"], h), sc

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"], cache["self"], cache["cross"]))
    x = apply_norm(cfg, x, params["final_norm"])
    logits = x[:, 0, :] @ params["unembed"].astype(cdt)
    return logits, {"self": new_self, "cross": cache["cross"],
                    "pos": pos + 1}
