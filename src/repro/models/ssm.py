"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD: intra-chunk quadratic (attention-like) term + inter-chunk
recurrent state passed with ``lax.scan``. Projections for z/x/B/C/dt are
separate matmuls (rather than one fused in_proj) so every output axis
shards cleanly on the model mesh axis.

Decode is the O(1)-per-token recurrence on the [H, N, P] state — this is
what makes the ``long_500k`` cell runnable for the SSM/hybrid archs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, rmsnorm, split_keys


def init_mamba2(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    gn = cfg.ssm_groups * cfg.ssm_state
    kw = cfg.ssm_conv
    ks = split_keys(key, ["wz", "wx", "wB", "wC", "wdt", "conv_x",
                          "conv_B", "conv_C", "wo", "A", "dt"])
    return {
        "wz": dense_init(ks["wz"], d, di, dtype),
        "wx": dense_init(ks["wx"], d, di, dtype),
        "wB": dense_init(ks["wB"], d, gn, dtype),
        "wC": dense_init(ks["wC"], d, gn, dtype),
        "wdt": dense_init(ks["wdt"], d, h, dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "conv_x": (jax.random.normal(ks["conv_x"], (kw, di), jnp.float32)
                   * (1.0 / kw)).astype(dtype),
        "conv_B": (jax.random.normal(ks["conv_B"], (kw, gn), jnp.float32)
                   * (1.0 / kw)).astype(dtype),
        "conv_C": (jax.random.normal(ks["conv_C"], (kw, gn), jnp.float32)
                   * (1.0 / kw)).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "gn_scale": jnp.ones((di,), jnp.float32),
        "wo": dense_init(ks["wo"], di, d, dtype),
    }


def _causal_dw_conv(x, w):
    """Depthwise causal 1D conv. x [B,S,W], w [K,W]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD scan. x [B,S,H,P]; dt [B,S,H] (>0); A [H] (<0);
    B,C [B,S,G,N]. Returns (y [B,S,H,P], final state [B,H,N,P])."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)

    dA = dtc * A[None, None, None, :]                 # [b,nc,L,h] (<0)
    cum = jnp.cumsum(dA, axis=2)                      # inclusive cumsum
    # intra-chunk: M[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, i>=j
    scores = jnp.einsum("bcihn,bcjhn->bchij", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    seg = cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3) \
        - cum[:, :, None, :, :].transpose(0, 1, 4, 2, 3)  # [b,nc,h,i,j]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, None], jnp.exp(seg), 0.0)
    M = scores * decay * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xc.astype(jnp.float32))

    # chunk summary states: S_c = sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
    dec_state = jnp.exp(cum[:, :, -1:, :] - cum)      # [b,nc,L,h]
    Sc = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp",
                    Bc.astype(jnp.float32), dec_state * dtc,
                    xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])           # [b,nc,h]

    def scan_body(carry, inp):
        s_c, dec = inp                                 # [b,h,n,p], [b,h]
        out = carry                                    # state BEFORE chunk
        new = carry * dec[..., None, None] + s_c
        return new, out

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_body, init,
        (Sc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [b,nc,h,n,p]

    y_off = jnp.einsum("bcihn,bcih,bchnp->bcihp",
                       Cc.astype(jnp.float32), jnp.exp(cum),
                       prev_states)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def mamba2_block(cfg: ModelConfig, params: Dict, x):
    """Training/prefill path. x [B,S,D] -> [B,S,D]."""
    b, s, d = x.shape
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    z = x @ params["wz"].astype(x.dtype)
    xin = x @ params["wx"].astype(x.dtype)
    Bv = x @ params["wB"].astype(x.dtype)
    Cv = x @ params["wC"].astype(x.dtype)
    dt = x @ params["wdt"].astype(x.dtype)

    xin = jax.nn.silu(_causal_dw_conv(xin, params["conv_x"].astype(x.dtype)))
    Bv = jax.nn.silu(_causal_dw_conv(Bv, params["conv_B"].astype(x.dtype)))
    Cv = jax.nn.silu(_causal_dw_conv(Cv, params["conv_C"].astype(x.dtype)))

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    y, _ = ssd_chunked(xin.reshape(b, s, h, p), dt, A,
                       Bv.reshape(b, s, g, n), Cv.reshape(b, s, g, n),
                       chunk=min(cfg.ssm_chunk, s))
    y = y + params["D"].astype(x.dtype)[None, None, :, None] \
        * xin.reshape(b, s, h, p)
    y = y.reshape(b, s, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["gn_scale"])
    return y @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Decode path: O(1) state update per token.
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, b: int, dtype=jnp.float32) -> Dict:
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    gn = cfg.ssm_groups * cfg.ssm_state
    kw = cfg.ssm_conv
    return {
        "state": jnp.zeros((b, h, n, p), jnp.float32),
        "conv_x": jnp.zeros((b, kw - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((b, kw - 1, gn), dtype),
        "conv_C": jnp.zeros((b, kw - 1, gn), dtype),
    }


def _conv_step(buf, xt, w):
    """buf [B,K-1,W]; xt [B,W]; w [K,W] -> (y [B,W], new buf)."""
    full = jnp.concatenate([buf, xt[:, None, :]], axis=1)   # [B,K,W]
    y = jnp.einsum("bkw,kw->bw", full, w)
    return y, full[:, 1:, :]


def mamba2_decode(cfg: ModelConfig, params: Dict, x, cache):
    """x [B,1,D] -> (y [B,1,D], new cache)."""
    b = x.shape[0]
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    xt = x[:, 0, :]
    z = xt @ params["wz"].astype(x.dtype)
    xin = xt @ params["wx"].astype(x.dtype)
    Bv = xt @ params["wB"].astype(x.dtype)
    Cv = xt @ params["wC"].astype(x.dtype)
    dt = xt @ params["wdt"].astype(x.dtype)

    xin, cbx = _conv_step(cache["conv_x"], xin,
                          params["conv_x"].astype(x.dtype))
    Bv, cbB = _conv_step(cache["conv_B"], Bv,
                         params["conv_B"].astype(x.dtype))
    Cv, cbC = _conv_step(cache["conv_C"], Cv,
                         params["conv_C"].astype(x.dtype))
    xin, Bv, Cv = map(jax.nn.silu, (xin, Bv, Cv))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None])
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                            # [B,H]
    xh = xin.reshape(b, h, p).astype(jnp.float32)
    Bh = jnp.repeat(Bv.reshape(b, g, n), h // g, axis=1)
    Ch = jnp.repeat(Cv.reshape(b, g, n), h // g, axis=1)
    new_state = cache["state"] * dA[..., None, None] + \
        jnp.einsum("bhn,bh,bhp->bhnp", Bh.astype(jnp.float32), dt, xh)
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), new_state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["gn_scale"])
    y = (y @ params["wo"].astype(x.dtype))[:, None, :]
    cache = {"state": new_state, "conv_x": cbx, "conv_B": cbB,
             "conv_C": cbC}
    return y, cache
