"""JAX model zoo for the assigned architectures."""
from .common import ModelConfig
from . import model_zoo, inputs
