"""GQA attention with RoPE: blockwise (flash-style) training path + KV-cache
decode path.

GQA is computed natively on grouped queries ([B, S, KV, G, hd] against
[B, S, KV, hd]) — the KV tensor is NEVER repeated to H heads (repeating a
32k llava cache would materialize 60 GB per layer). The training/prefill
path never materializes the [S, S] score matrix either: it scans KV
chunks with an online softmax, so 32k prefill compiles with O(S * chunk)
live memory.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, dense_init, rope_freqs, \
    split_keys

KV_CHUNK = 1024


def init_attn(cfg: ModelConfig, key, d_model: Optional[int] = None,
              n_heads: Optional[int] = None,
              n_kv: Optional[int] = None, dtype=jnp.float32) -> Dict:
    d = d_model or cfg.d_model
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    hd = cfg.hd
    ks = split_keys(key, ["wq", "wk", "wv", "wo"])
    return {
        "wq": dense_init(ks["wq"], d, h * hd, dtype),
        "wk": dense_init(ks["wk"], d, kv * hd, dtype),
        "wv": dense_init(ks["wv"], d, kv * hd, dtype),
        "wo": dense_init(ks["wo"], h * hd, d, dtype),
    }


def flash_attention(q, k, v, causal: bool, q_offset: int = 0,
                    chunk: int = KV_CHUNK):
    """Online-softmax attention with native GQA.

    q [B, Sq, H, hd]; k/v [B, Skv, KV, hd] with H = KV * G.
    Returns [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    chunk = min(chunk, skv)
    while skv % chunk:
        chunk -= 1  # largest divisor of skv below the target chunk
    n_chunks = skv // chunk
    scale = jnp.asarray(1.0 / (hd ** 0.5), q.dtype)
    qf = (q * scale).reshape(b, sq, kv, g, hd)
    q_pos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, n_chunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        acc, m, l = carry                  # [b,sq,kv,g,hd],[b,kv,g,sq]x2
        kb, vb, ci = xs
        # operands stay in model dtype; accumulate fp32 (upcasting the
        # operands would hoist fp32 copies of K/V out of the scan)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kb.astype(q.dtype),
                       preferred_element_type=jnp.float32)
        if causal:
            k_pos = ci * chunk + jnp.arange(chunk)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None, None, :, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(q.dtype),
                        vb.astype(q.dtype),
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, sq, kv, g, hd), jnp.float32)
    m0 = jnp.full((b, kv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    # remat per KV chunk: backward recomputes the [.., sq, chunk] score
    # block instead of saving n_chunks of them (7 GiB/layer at 4k train)
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(body), (acc0, m0, l0),
        (kc, vc, jnp.arange(n_chunks)))
    norm = l.transpose(0, 3, 1, 2)[..., None]
    out = acc / jnp.maximum(norm, 1e-20)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def attention(cfg: ModelConfig, params: Dict, x, *, causal=True,
              positions=None, kv_x=None, kv_positions=None,
              n_heads=None, n_kv=None):
    """Full (pre)fill attention. ``kv_x`` enables cross-attention."""
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    hd = cfg.hd
    b, s, _ = x.shape
    src = kv_x if kv_x is not None else x
    sk = src.shape[1]
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (src @ params["wk"].astype(x.dtype)).reshape(b, sk, kv, hd)
    v = (src @ params["wv"].astype(x.dtype)).reshape(b, sk, kv, hd)
    if positions is None:
        positions = jnp.arange(s)
    if kv_x is None and cfg.use_rope:  # self-attention: RoPE on both
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(q, cos, sin)
        kcos, ksin = rope_freqs(
            cfg, kv_positions if kv_positions is not None else positions)
        k = apply_rope(k, kcos, ksin)
    out = flash_attention(q, k, v, causal=causal)
    return out.reshape(b, s, h * hd) @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# KV-cache decode path
# ---------------------------------------------------------------------------

def init_kv_cache(b: int, s_max: int, n_kv: int, hd: int,
                  dtype=jnp.bfloat16):
    return {"k": jnp.zeros((b, s_max, n_kv, hd), dtype),
            "v": jnp.zeros((b, s_max, n_kv, hd), dtype)}


def prefill_into_cache(cfg: ModelConfig, params, x, cache, *,
                       n_heads=None, n_kv=None):
    """Run prefill attention AND write k/v into the cache at [0, S)."""
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    hd = cfg.hd
    b, s, _ = x.shape
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, s, kv, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, s, kv, hd)
    if cfg.use_rope:
        pos = jnp.arange(s)
        cos, sin = rope_freqs(cfg, pos)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }
    out = flash_attention(q, k, v, causal=True)
    y = out.reshape(b, s, h * hd) @ params["wo"].astype(x.dtype)
    return y, cache


def gqa_decode_attend(q, ck, cv, pos):
    """q [B,1,H,hd] against cache [B,S,KV,hd] without repeating KV.

    Inputs stay in cache dtype with fp32 ACCUMULATION
    (preferred_element_type) — upcasting the cache operand would make XLA
    materialize an fp32 copy of the whole stacked cache outside the layer
    scan (observed +100 GiB on llava decode_32k)."""
    b, _, h, hd = q.shape
    s_max, kv = ck.shape[1], ck.shape[2]
    g = h // kv
    scale = jnp.asarray(1.0 / (hd ** 0.5), q.dtype)
    qg = (q * scale).reshape(b, kv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, ck.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    mask = jnp.arange(s_max)[None, None, None, :] <= pos
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype),
                     cv.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h * hd)


def decode_attention(cfg: ModelConfig, params, x, cache, pos, *,
                     n_heads=None, n_kv=None,
                     rope: Optional[bool] = None):
    """One-token decode: x [B, 1, D]; cache k/v [B, S_max, kv, hd]."""
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    hd = cfg.hd
    b = x.shape[0]
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, 1, h, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, 1, kv, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, 1, kv, hd)
    if cfg.use_rope if rope is None else rope:
        cos, sin = rope_freqs(cfg, jnp.asarray(pos)[None])
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    out = gqa_decode_attend(q, ck, cv, pos)
    y = out.astype(x.dtype) @ params["wo"].astype(x.dtype)
    return y, {"k": ck, "v": cv}
