"""Batch construction: real arrays for tests/training, ShapeDtypeStructs
for the dry-run (no allocation)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig

PyTree = Any


def train_batch_shapes(cfg: ModelConfig, batch: int,
                       seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return out


def decode_token_shapes(cfg: ModelConfig,
                        batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch,), jnp.int32)


def make_train_batch(cfg: ModelConfig, batch: int, seq: int,
                     seed: int = 0) -> Dict:
    rng = np.random.RandomState(seed)
    toks = rng.randint(0, cfg.vocab, size=(batch, seq + 1)).astype(np.int32)
    out = {"tokens": jnp.asarray(toks[:, :-1]),
           "labels": jnp.asarray(toks[:, 1:])}
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.randn(batch, cfg.enc_frames, cfg.d_model),
            dtype=cfg.compute_dtype)
    return out


def make_decode_tokens(cfg: ModelConfig, batch: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, cfg.vocab, size=(batch,)),
                       dtype=jnp.int32)
