"""Lowering LLM blocks (``ModelConfig``) into 7D ``LayerSpec`` networks.

The mapping core speaks conv/matmul loop nests (``core.workload``); the
model zoo speaks ``ModelConfig``. This module translates one decoder
*block* of each architecture family into a ``LayerSpec`` chain plus the
dependency ``Edge``s that feed overlap analysis — the same contract the
hand-written resnet/bert networks satisfy — so the overlap search, the
DSE sweeps and the mapping service answer PIM questions for LLM
inference traffic.

Conventions (DESIGN.md Section 15):

* **Phases.** ``prefill`` lowers seq x seq attention (score/context
  matmuls head-folded exactly like ``describe_bert``); ``decode`` lowers
  one q_len=1 step against a KV length ``kv_len`` — decode shapes depend
  on ``kv_len`` only, never on any prefill sequence length.
* **Tranches.** A model's ``n_layers`` identical blocks would multiply
  search cost for zero information (every block is the same subproblem),
  so one block is lowered per *tranche* of identical layers: dense/MoE/
  SSM models lower one block, hybrids (zamba2) lower one SSM block plus
  the shared attention block, whisper lowers the conv stem + one encoder
  + one decoder block. ``blocks=N`` chains N copies of the repeating
  tranche for inter-block overlap studies. Whole-model totals scale the
  per-block result by the block count (``run.py workloads`` prints both).
* **Exclusions.** Elementwise work is not lowered: norms, softmax,
  rotary embedding, activation functions, the router's top-k
  gate/select, depthwise causal convs (per-channel, MAC-free in the 7D
  sense), residual adds, and the embedding/unembed lookups that sit
  outside the lowered block. ``sum(l.macs)`` over a lowered block is
  therefore exactly the block's projection/attention/expert/scan matmul
  FLOPs — pinned by the golden accounting tests.
* **Edges.** Affine tile-to-tile reuse keeps the exact coordinate maps
  (``IdentityMap``, ``HeadFoldMap``/``HeadUnfoldMap``, grouped
  ``WeightMap`` for GQA); structure-free mappings (MoE dispatch/combine,
  KV-cache appends, SSD inter-chunk state, token<->spatial flattens) use
  the conservative ``FullMap`` (consumer waits for the producer's whole
  output) — correct, just overlap-pessimistic, and documented per edge
  below.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

from ..core.overlap import (Edge, FullMap, HeadFoldMap, HeadUnfoldMap,
                            IdentityMap, WeightMap)
from ..core.workload import LayerSpec, conv, matmul
from ..models.common import ModelConfig

PHASES = ("prefill", "decode")

#: producer reference a block hands to its consumer: (layer index, how the
#: consumer's entry layers should read it — "identity" for token-aligned
#: outputs, "full" for scatter/gather-shaped ones)
Producer = Tuple[int, str]


def _edge(idx: int, kind: str) -> Edge:
    return Edge(idx, IdentityMap() if kind == "identity" else FullMap())


class NetBuilder:
    """Accumulates (layers, edges) while lowering; producers are always
    appended before their consumers, so edges can only point backward."""

    def __init__(self):
        self.layers: List[LayerSpec] = []
        self.edges: List[List[Edge]] = []

    def add(self, layer: LayerSpec, deps: Sequence[Edge] = ()) -> int:
        """Append one layer with its dependency edges; returns its index."""
        for e in deps:
            assert 0 <= e.producer < len(self.layers), e.producer
        self.layers.append(layer)
        self.edges.append(list(deps))
        return len(self.layers) - 1


def moe_capacity(cfg: ModelConfig, tokens: int) -> int:
    """Per-expert slot count of the capacity-view dispatch: each of the
    ``n_experts`` experts processes ``ceil(T/moe_shards * top_k/E *
    capacity_factor)`` tokens (the GShard einsum-dispatch shape the model
    code ablates against), never fewer than one."""
    per_shard = tokens / max(cfg.moe_shards, 1)
    cap = math.ceil(per_shard * cfg.top_k / cfg.n_experts
                    * cfg.capacity_factor)
    return max(1, cap)


def _ffn(b: NetBuilder, cfg: ModelConfig, inputs: Sequence[Producer],
         prefix: str, tokens: int, d_in: int, d_ff: int) -> List[Producer]:
    """One MLP: swiglu = gate/up in parallel + down consuming both (the
    elementwise gate multiply is excluded); gelu = ffn1 -> ffn2."""
    deps = [_edge(i, k) for i, k in inputs]
    if cfg.mlp == "swiglu":
        gate = b.add(matmul(f"{prefix}ffn_gate", tokens, d_in, d_ff), deps)
        up = b.add(matmul(f"{prefix}ffn_up", tokens, d_in, d_ff), deps)
        down = b.add(matmul(f"{prefix}ffn_down", tokens, d_ff, d_in),
                     [Edge(gate, IdentityMap()), Edge(up, IdentityMap())])
    else:
        f1 = b.add(matmul(f"{prefix}ffn1", tokens, d_in, d_ff), deps)
        down = b.add(matmul(f"{prefix}ffn2", tokens, d_ff, d_in),
                     [Edge(f1, IdentityMap())])
    return [(down, "identity")]


def _attention(b: NetBuilder, cfg: ModelConfig, inputs: Sequence[Producer],
               prefix: str, q_len: int, kv_len: int,
               kv_inputs: Optional[Sequence[Producer]] = None
               ) -> List[Producer]:
    """One (self or cross) attention sublayer, GQA-aware.

    * prefill self-attention (``q_len == kv_len``, ``kv_inputs is
      None``): the bert wiring generalized — QK reads Q through
      ``HeadFoldMap`` and K-proj as its stationary operand through a
      ``group``ed ``WeightMap``; AV likewise for V.
    * decode self-attention (``q_len == 1``): K/V projections produce
      only the newly appended token, the rest of the KV cache predates
      the request (ready at t=0) — so QK/AV depend on the fresh K/V via
      ``FullMap`` (wait for the one-token projection) and on Q/scores
      via the exact maps.
    * cross-attention (``kv_inputs`` set — whisper): K/V project the
      encoder output, exact ``WeightMap`` edges at ``kv_len`` columns.
    """
    h, kvh, hd = cfg.n_heads, max(cfg.n_kv_heads, 1), cfg.hd
    group = max(1, h // kvh)
    deps = [_edge(i, k) for i, k in inputs]
    q = b.add(matmul(f"{prefix}q_proj", q_len, cfg.d_model, h * hd), deps)
    kv_deps = ([_edge(i, k) for i, k in kv_inputs]
               if kv_inputs is not None else deps)
    kv_tokens = kv_len if kv_inputs is not None else q_len
    k = b.add(matmul(f"{prefix}k_proj", kv_tokens, cfg.d_model, kvh * hd),
              kv_deps)
    v = b.add(matmul(f"{prefix}v_proj", kv_tokens, cfg.d_model, kvh * hd),
              kv_deps)
    decode_cache = kv_inputs is None and q_len == 1 and kv_len > q_len
    if decode_cache:
        k_edge = Edge(k, FullMap())      # cache append: wait for new K
        v_edge = Edge(v, FullMap())
    else:
        k_edge = Edge(k, WeightMap(q_len, hd, "qk_weight", group))
        v_edge = Edge(v, WeightMap(q_len, hd, "av_weight", group))
    qk = b.add(matmul(f"{prefix}qk", q_len, hd, kv_len, batch=h),
               [Edge(q, HeadFoldMap(q_len, hd)), k_edge])
    av = b.add(matmul(f"{prefix}av", q_len, kv_len, hd, batch=h),
               [Edge(qk, IdentityMap()), v_edge])
    out = b.add(matmul(f"{prefix}out_proj", q_len, h * hd, cfg.d_model),
                [Edge(av, HeadUnfoldMap(q_len, hd))])
    return [(out, "identity")]


def _dense_block(b: NetBuilder, cfg: ModelConfig,
                 inputs: Sequence[Producer], prefix: str,
                 q_len: int, kv_len: int) -> List[Producer]:
    """Attention + MLP — the dense/vlm decoder block (and zamba2's shared
    attention block)."""
    attn = _attention(b, cfg, inputs, prefix, q_len, kv_len)
    return _ffn(b, cfg, attn, prefix, q_len, cfg.d_model, cfg.d_ff)


def _moe_block(b: NetBuilder, cfg: ModelConfig,
               inputs: Sequence[Producer], prefix: str,
               q_len: int, kv_len: int) -> List[Producer]:
    """Attention + router + shared experts + top-k routed expert fan-out.

    The router is a plain ``tokens x d_model x n_experts`` matmul (its
    softmax/top-k select is elementwise, excluded). Shared experts see
    every token in order (exact identity edges); each of the
    ``n_experts`` routed experts is lowered at its ``moe_capacity`` slot
    count with ``FullMap`` fan-out edges from both the router (dispatch
    waits on routing values) and the attention output (token gather).
    The combine is a scatter-add, so expert outputs re-enter downstream
    consumers as ``full`` producers (fan-in)."""
    attn = _attention(b, cfg, inputs, prefix, q_len, kv_len)
    attn_deps = [_edge(i, k) for i, k in attn]
    router = b.add(matmul(f"{prefix}router", q_len, cfg.d_model,
                          cfg.n_experts), attn_deps)
    outs: List[Producer] = []
    for s in range(cfg.n_shared_experts):
        outs += _ffn(b, cfg, attn, f"{prefix}shared{s}.", q_len,
                     cfg.d_model, cfg.d_ff)
    cap = moe_capacity(cfg, q_len)
    fan_out: List[Producer] = [(router, "full")] + \
        [(i, "full") for i, _ in attn]
    for e in range(cfg.n_experts):
        (down, _), = _ffn(b, cfg, fan_out, f"{prefix}exp{e}.", cap,
                          cfg.d_model, cfg.d_ff)
        outs.append((down, "full"))
    return outs


def _ssd_block(b: NetBuilder, cfg: ModelConfig,
               inputs: Sequence[Producer], prefix: str,
               phase: str, tokens: int) -> List[Producer]:
    """Mamba-2 SSD block as its matmul skeleton (``models/ssm.py``).

    Prefill lowers the chunked dual: five input projections (z/x/B/C/dt
    are separate matmuls in the model too), the intra-chunk score matmul
    ``C B^T`` and its application to x, the chunk-state contraction
    ``B^T (dt x)`` and the inter-chunk state readout ``C . state`` —
    each batched over ``n_chunks * ssm_heads`` (B/C are materialized
    per-head by the reference scan). Depthwise convs / cumsum decays /
    the z-gate are elementwise, excluded. Decode is the O(1) recurrence:
    projections at one token, the ``B x^T`` state outer product and the
    ``C . state`` readout. Reshapes between token space and (chunk,
    head) space are not affine in 7D, so intra-block edges past the
    score->apply identity are conservative ``FullMap``s."""
    d, di = cfg.d_model, cfg.d_inner
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    deps = [_edge(i, k) for i, k in inputs]
    z = b.add(matmul(f"{prefix}z_proj", tokens, d, di), deps)
    x = b.add(matmul(f"{prefix}x_proj", tokens, d, di), deps)
    bp = b.add(matmul(f"{prefix}b_proj", tokens, d, g * n), deps)
    cp = b.add(matmul(f"{prefix}c_proj", tokens, d, g * n), deps)
    dt = b.add(matmul(f"{prefix}dt_proj", tokens, d, h), deps)
    if phase == "prefill":
        c = min(cfg.ssm_chunk, tokens)
        nc = math.ceil(tokens / c)
        scores = b.add(matmul(f"{prefix}ssd_scores", c, n, c, batch=nc * h),
                       [Edge(cp, FullMap()), Edge(bp, FullMap()),
                        Edge(dt, FullMap())])
        y_diag = b.add(matmul(f"{prefix}ssd_ydiag", c, c, p, batch=nc * h),
                       [Edge(scores, IdentityMap()), Edge(x, FullMap())])
        states = b.add(matmul(f"{prefix}ssd_state", n, c, p, batch=nc * h),
                       [Edge(bp, FullMap()), Edge(x, FullMap()),
                        Edge(dt, FullMap())])
        y_off = b.add(matmul(f"{prefix}ssd_yoff", c, n, p, batch=nc * h),
                      [Edge(cp, FullMap()), Edge(states, FullMap())])
        out = b.add(matmul(f"{prefix}out_proj", tokens, di, d),
                    [Edge(y_diag, FullMap()), Edge(y_off, FullMap()),
                     Edge(z, FullMap())])
    else:
        upd = b.add(matmul(f"{prefix}ssd_state", n, 1, p, batch=h),
                    [Edge(bp, FullMap()), Edge(x, FullMap()),
                     Edge(dt, FullMap())])
        y = b.add(matmul(f"{prefix}ssd_y", 1, n, p, batch=h),
                  [Edge(cp, FullMap()), Edge(upd, FullMap())])
        out = b.add(matmul(f"{prefix}out_proj", 1, di, d),
                    [Edge(y, FullMap()), Edge(z, FullMap())])
    return [(out, "identity")]


def _whisper_frontend(b: NetBuilder, cfg: ModelConfig) -> List[Producer]:
    """Whisper conv stem: two 1D convs over the mel features (80 bins ->
    d_model channels, stride 2 halves 2*enc_frames mel frames down to
    enc_frames encoder positions), lowered as Q=1 conv ``LayerSpec``s
    chained with exact identity edges (1D conv output channel/position
    align with the encoder matmuls' C/P — ``chain_edges`` semantics)."""
    frames = 2 * cfg.enc_frames
    c1 = b.add(LayerSpec("stem.conv1", K=cfg.d_model, C=80, P=frames, Q=1,
                         R=3, S=1, pad=1))
    c2 = b.add(LayerSpec("stem.conv2", K=cfg.d_model, C=cfg.d_model,
                         P=cfg.enc_frames, Q=1, R=3, S=1, stride=2, pad=1),
               [Edge(c1, IdentityMap())])
    return [(c2, "identity")]


def _vision_frontend(b: NetBuilder, cfg: ModelConfig) -> List[Producer]:
    """LLaVA vision tower stub: a 14x14/stride-14 patch-embed conv over
    the image grid (square when ``img_tokens`` is a perfect square, else
    a 1D strip) plus the multimodal projector matmul. The spatial->token
    flatten between them is not affine in 7D -> ``FullMap``."""
    gh = math.isqrt(cfg.img_tokens)
    gh, gw = (gh, gh) if gh * gh == cfg.img_tokens else (cfg.img_tokens, 1)
    patch = b.add(LayerSpec("vision.patch_embed", K=cfg.d_model, C=3,
                            P=gh, Q=gw, R=14, S=14, stride=14))
    proj = b.add(matmul("vision.projector", cfg.img_tokens, cfg.d_model,
                        cfg.d_model), [Edge(patch, FullMap())])
    return [(proj, "full")]


def _audio_net(b: NetBuilder, cfg: ModelConfig, phase: str,
               seq: int, kv_len: int, blocks: int) -> None:
    """Whisper: prefill = conv stem -> encoder block -> cross-K/V
    projections -> decoder block(s) (self + cross attention + MLP);
    decode = one decoder step whose cross K/V come from the primed
    cache (no producer -> ready at t=0)."""
    f = cfg.enc_frames
    cross_kv: Optional[List[Producer]] = None
    if phase == "prefill":
        stem = _whisper_frontend(b, cfg)
        enc_attn = _attention(b, cfg, stem, "enc.", f, f)
        enc = _ffn(b, cfg, enc_attn, "enc.", f, cfg.d_model, cfg.d_ff)
        cross_kv = enc
    q_len = seq if phase == "prefill" else 1
    inputs: List[Producer] = []
    for i in range(blocks):
        pre = f"dec{i}." if blocks > 1 else "dec."
        self_out = _attention(b, cfg, inputs, pre + "self.", q_len,
                              q_len if phase == "prefill" else kv_len)
        if cross_kv is not None:
            cross_out = _attention(b, cfg, self_out, pre + "cross.",
                                   q_len, f, kv_inputs=cross_kv)
        else:
            # decode: cross K/V are cached — q-only edges, kv at t=0
            cq = b.add(matmul(pre + "cross.q_proj", q_len, cfg.d_model,
                              cfg.n_heads * cfg.hd),
                       [_edge(j, k) for j, k in self_out])
            qk = b.add(matmul(pre + "cross.qk", q_len, cfg.hd, f,
                              batch=cfg.n_heads),
                       [Edge(cq, HeadFoldMap(q_len, cfg.hd))])
            av = b.add(matmul(pre + "cross.av", q_len, f, cfg.hd,
                              batch=cfg.n_heads),
                       [Edge(qk, IdentityMap())])
            out = b.add(matmul(pre + "cross.out_proj", q_len,
                               cfg.n_heads * cfg.hd, cfg.d_model),
                        [Edge(av, HeadUnfoldMap(q_len, cfg.hd))])
            cross_out = [(out, "identity")]
        inputs = _ffn(b, cfg, cross_out, pre, q_len, cfg.d_model, cfg.d_ff)


def lower(cfg: ModelConfig, phase: str = "prefill", seq: int = 2048,
          kv_len: int = 1024, blocks: int = 1
          ) -> Tuple[List[LayerSpec], List[List[Edge]]]:
    """Lower ``blocks`` tranche blocks of ``cfg`` into (layers, edges).

    ``phase="prefill"`` uses ``seq`` (the prompt length); ``phase=
    "decode"`` uses ``kv_len`` (the context the step attends over) and
    is independent of ``seq`` by construction. Families: ``dense``/
    ``vlm`` -> attention+MLP blocks (vlm prefill prepends the vision
    frontend and its ``img_tokens``), ``moe`` -> attention + shared/
    routed expert fan-out, ``ssm`` -> SSD skeleton, ``hybrid`` -> one
    SSD block + the shared attention block per tranche, ``audio`` ->
    whisper stem/encoder/decoder."""
    if phase not in PHASES:
        raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")
    if seq < 1 or kv_len < 1 or blocks < 1:
        raise ValueError(f"seq/kv_len/blocks must be >= 1, got "
                         f"{seq}/{kv_len}/{blocks}")
    b = NetBuilder()
    fam = cfg.family
    if fam == "audio":
        _audio_net(b, cfg, phase, seq, kv_len, blocks)
        return b.layers, b.edges
    inputs: List[Producer] = []
    if fam == "vlm" and phase == "prefill":
        inputs = _vision_frontend(b, cfg)
        seq = seq + cfg.img_tokens   # image tokens prepend the prompt
    q_len, kv = (seq, seq) if phase == "prefill" else (1, kv_len)
    for i in range(blocks):
        pre = f"b{i}." if blocks > 1 else ""
        if fam == "moe":
            inputs = _moe_block(b, cfg, inputs, pre, q_len, kv)
        elif fam == "ssm":
            inputs = _ssd_block(b, cfg, inputs, pre, phase, q_len)
        elif fam == "hybrid":
            inputs = _ssd_block(b, cfg, inputs, pre + "ssm.", phase, q_len)
            inputs = _dense_block(b, cfg, inputs, pre + "attn.", q_len, kv)
        else:                        # dense, vlm
            inputs = _dense_block(b, cfg, inputs, pre, q_len, kv)
    return b.layers, b.edges
