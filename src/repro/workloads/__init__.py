"""LLM workload lowering: the model zoo as overlap-searchable networks.

``repro.models``/``repro.configs`` define ten LM architectures as JAX
programs; ``repro.core`` searches PIM mappings over 7D loop-nest
networks. This package is the bridge: ``lower`` turns one ``ModelConfig``
block into ``LayerSpec`` chains + dependency ``Edge``s, and ``scenarios``
names the interesting shapes (``deepseek_moe_16b:prefill@2048``,
``mamba2_780m:decode@1``, smoke variants) so every existing entry point —
``describe``/``get_network``, ``run.py dse --network``, a
``MappingRequest`` — accepts the whole zoo unchanged. Conventions are
specified in DESIGN.md Section 15.
"""
from .lowering import (NetBuilder, PHASES, lower, moe_capacity)
from .scenarios import (DEFAULT_DECODE_KV, DEFAULT_PREFILL_SEQ,
                        SMOKE_DECODE_KV, SMOKE_PREFILL_SEQ, Scenario,
                        describe_scenario, is_scenario_name,
                        list_scenarios, lower_scenario, parse_scenario,
                        scenario_layers)

__all__ = [n for n in dir() if not n.startswith("_")]
