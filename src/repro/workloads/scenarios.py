"""Named scenarios: ``arch:phase@length`` strings over the model zoo.

Every entry point that accepts a network name (``core.interface.
describe``, ``core.workload.get_network``, ``run.py dse --network``, a
``MappingRequest``) also accepts a *scenario* string:

    deepseek_moe_16b:prefill@2048      # 2048-token prompt, one MoE block
    mamba2_780m:decode@1               # one decode step
    granite_8b_smoke:prefill@64x2      # smoke config, two chained blocks

Grammar: ``<arch>[:phase][@length][xblocks]`` where ``arch`` is a zoo id
(dashes allowed, ``_smoke``/``-smoke`` suffix selects the reduced
same-family smoke config), ``phase`` defaults to ``prefill``, ``length``
is the prompt length (prefill) or KV/context length (decode) and
``blocks`` chains that many tranche blocks. Defaults and the canonical
per-arch names live in ``list_scenarios``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import List, Optional, Tuple

from ..configs import ARCH_IDS, get_config
from ..core.interface import NetworkDesc
from ..core.workload import LayerSpec
from ..models.common import ModelConfig
from .lowering import PHASES, lower

#: default lengths of scenario names that omit ``@length``
DEFAULT_PREFILL_SEQ = 2048
DEFAULT_DECODE_KV = 1024
SMOKE_PREFILL_SEQ = 64
SMOKE_DECODE_KV = 16

_SCENARIO_RE = re.compile(
    r"^(?P<arch>[A-Za-z][A-Za-z0-9_\-]*?)"
    r"(?::(?P<phase>[a-z]+))?"
    r"(?:@(?P<length>\d+))?"
    r"(?:x(?P<blocks>\d+))?$")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One parsed scenario: which config, which phase, which shapes."""

    arch_id: str                 # resolved zoo id (without _smoke)
    smoke: bool
    phase: str                   # prefill | decode
    length: int                  # seq (prefill) / kv context (decode)
    blocks: int = 1

    @property
    def name(self) -> str:
        """Canonical round-trippable scenario string."""
        suffix = "" if self.blocks == 1 else f"x{self.blocks}"
        arch = self.arch_id + ("_smoke" if self.smoke else "")
        return f"{arch}:{self.phase}@{self.length}{suffix}"

    def config(self) -> ModelConfig:
        """The ``ModelConfig`` this scenario lowers."""
        return get_config(self.arch_id, smoke=self.smoke)


def _resolve_arch(token: str) -> Optional[Tuple[str, bool]]:
    """Zoo id + smoke flag of an arch token, or None if unknown."""
    norm = token.replace("-", "_")
    smoke = norm.endswith("_smoke")
    if smoke:
        norm = norm[:-len("_smoke")]
    return (norm, smoke) if norm in ARCH_IDS else None


def parse_scenario(name: str, *, seq: Optional[int] = None,
                   kv_len: Optional[int] = None,
                   blocks: Optional[int] = None) -> Scenario:
    """Parse ``arch[:phase][@length][xblocks]``; keyword overrides win
    over the string (and fill in omitted parts). Raises ``KeyError`` for
    an unknown arch and ``ValueError`` for a malformed phase/shape."""
    m = _SCENARIO_RE.match(name)
    arch = _resolve_arch(m.group("arch")) if m else None
    if arch is None:
        raise KeyError(f"unknown network/scenario {name!r}; zoo archs: "
                       f"{list(ARCH_IDS)} (grammar: "
                       "'<arch>[:phase][@length][xblocks]')")
    arch_id, smoke = arch
    phase = m.group("phase") or "prefill"
    if phase not in PHASES:
        raise ValueError(f"scenario {name!r}: phase must be one of "
                         f"{PHASES}, got {phase!r}")
    length = int(m.group("length")) if m.group("length") else None
    if phase == "prefill":
        length = seq if seq is not None else length
        if length is None:
            length = SMOKE_PREFILL_SEQ if smoke else DEFAULT_PREFILL_SEQ
    else:
        length = kv_len if kv_len is not None else length
        if length is None:
            length = SMOKE_DECODE_KV if smoke else DEFAULT_DECODE_KV
    n_blocks = blocks if blocks is not None else \
        int(m.group("blocks") or 1)
    if length < 1 or n_blocks < 1:
        raise ValueError(f"scenario {name!r}: length and blocks must be "
                         f">= 1, got {length}/{n_blocks}")
    return Scenario(arch_id=arch_id, smoke=smoke, phase=phase,
                    length=length, blocks=n_blocks)


def is_scenario_name(name: str) -> bool:
    """Cheap syntactic check: does ``name`` parse as a zoo scenario?
    (No layers are built — safe for request validation hot paths.)"""
    try:
        parse_scenario(name)
        return True
    except (KeyError, ValueError):
        return False


def lower_scenario(sc: Scenario) -> Tuple[List[LayerSpec], list]:
    """(layers, edges) of one parsed scenario."""
    cfg = sc.config()
    if sc.phase == "prefill":
        return lower(cfg, "prefill", seq=sc.length, blocks=sc.blocks)
    return lower(cfg, "decode", kv_len=sc.length, blocks=sc.blocks)


def describe_scenario(name: str, **kw) -> NetworkDesc:
    """``core.interface.describe`` backend for scenario names. Accepted
    kwargs: ``seq`` (prefill length), ``kv_len`` (decode context),
    ``blocks`` — anything else raises ``TypeError`` (a typo'd shape
    silently ignored would search the wrong workload)."""
    known = {"seq", "kv_len", "blocks"}
    unknown = sorted(set(kw) - known)
    if unknown:
        raise TypeError(f"describe({name!r}): unexpected kwargs "
                        f"{unknown}; scenarios take {sorted(known)}")
    sc = parse_scenario(name, **{k: kw[k] for k in known if k in kw})
    layers, edges = lower_scenario(sc)
    return NetworkDesc(name=sc.name, layers=layers, edges=edges)


def scenario_layers(name: str) -> List[LayerSpec]:
    """``core.workload.get_network`` backend: layers only."""
    return lower_scenario(parse_scenario(name))[0]


def list_scenarios(smoke: bool = False) -> List[str]:
    """Canonical scenario names — every zoo arch x {prefill, decode} at
    the default lengths (smoke variants at smoke lengths)."""
    pf = SMOKE_PREFILL_SEQ if smoke else DEFAULT_PREFILL_SEQ
    kv = SMOKE_DECODE_KV if smoke else DEFAULT_DECODE_KV
    names = []
    for a in ARCH_IDS:
        arch = a + ("_smoke" if smoke else "")
        names.append(f"{arch}:prefill@{pf}")
        names.append(f"{arch}:decode@{kv}")
    return names
