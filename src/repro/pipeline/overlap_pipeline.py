"""Overlap-scheduled pipeline parallelism (paper technique at mesh level).

PIM channels holding consecutive layers map to pipeline stages on a mesh
axis; the paper's computational overlap (Fig 3b: layer n+1 starts on the
data spaces layer n has finished) becomes a microbatch wavefront: stage s
processes microbatch m at tick t = m + s, activations hop stages via
``ppermute`` — compute of tick t overlaps the send of tick t-1.

The paper's *transformation* (Section IV-I: re-sort data spaces by ready
time, round-robin across instances) maps to the microbatch emission
order: ``overlap_schedule`` feeds per-microbatch ready times through
``core.transform.transform_schedule`` and returns the emission order the
wavefront uses. For uniform arrivals it is the identity; for skewed
arrivals (e.g. streamed requests) it provably minimizes the makespan of
the first stage (same sort argument as the paper's).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.transform import transform_schedule


def overlap_schedule(ready_times: np.ndarray, step_ns: float = 1.0
                     ) -> np.ndarray:
    """Microbatch emission order from the paper's transformation: process
    in ascending input-ready order."""
    ready = np.asarray(ready_times, np.float64)[None, :]
    tr = transform_schedule(ready, step_ns)
    # transform_schedule sorts ascending; recover the order
    return np.argsort(ready[0], kind="stable")


def pipeline_forward(stage_fn: Callable, stage_params, x,
                     mesh: Mesh, axis: str = "stage",
                     order: Optional[np.ndarray] = None):
    """Run ``n_micro`` microbatches through ``n_stages`` pipeline stages.

    stage_fn(params_one_stage, act) -> act, applied by every device to the
    microbatch currently resident on its stage; activations advance one
    stage per tick via collective_permute.

    x: [n_micro, ...] microbatches (replicated across the stage axis).
    stage_params: pytree with leading [n_stages] axis, sharded on
    ``axis``. Returns [n_micro, ...] outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    if order is not None:
        x = x[np.asarray(order)]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def spmd(params_local, x_local):
        # params_local: [1, ...] (this stage); x_local: [n_micro, ...]
        sid = jax.lax.axis_index(axis)
        p_one = jax.tree_util.tree_map(lambda a: a[0], params_local)

        def tick(carry, t):
            state, outs = carry
            midx = t - sid                       # microbatch at this stage
            valid = (midx >= 0) & (midx < n_micro)
            midx_c = jnp.clip(midx, 0, n_micro - 1)
            inp = jnp.where(sid == 0,
                            x_local[midx_c],     # stage 0 ingests
                            state)               # others consume upstream
            act = stage_fn(p_one, inp)
            act = jnp.where(valid, act, state)
            outs = jax.lax.cond(
                valid & (sid == n_stages - 1),
                lambda o: o.at[midx_c].set(act),
                lambda o: o, outs)
            nxt = jax.lax.ppermute(act, axis, perm)
            return (nxt, outs), None

        state0 = jnp.zeros_like(x_local[0])
        outs0 = jnp.zeros_like(x_local)
        (_, outs), _ = jax.lax.scan(
            tick, (state0, outs0), jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast via psum
        outs = jnp.where(sid == n_stages - 1, outs,
                         jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    in_specs = (jax.tree_util.tree_map(lambda _: P(axis), stage_params),
                P())
    out = shard_map(spmd, mesh=mesh, in_specs=in_specs, out_specs=P(),
                    check_rep=False)(stage_params, x)
    if order is not None:
        inv = np.empty_like(order)
        inv[np.asarray(order)] = np.arange(len(order))
        out = out[inv]
    return out


def sequential_reference(stage_fn: Callable, stage_params, x):
    """Oracle: apply all stages sequentially to every microbatch."""
    n_stages = jax.tree_util.tree_leaves(stage_params)[0].shape[0]

    def one(mb):
        act = mb
        for s in range(n_stages):
            p = jax.tree_util.tree_map(lambda a: a[s], stage_params)
            act = stage_fn(p, act)
        return act

    return jax.vmap(one)(x)
