"""pipeline subpackage."""
