"""data subpackage."""
