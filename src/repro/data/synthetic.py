"""Deterministic synthetic token pipeline.

Stateless-seeded: batch ``i`` is a pure function of (seed, step, shard),
so any host can regenerate any batch after a failure/elastic re-shard —
the data-side half of the fault-tolerance story (DESIGN.md Section 7).

The stream is a order-2 Markov chain over the vocab (not iid uniform) so
a ~100M-parameter model shows a real, monotonically decreasing loss in
the end-to-end example.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    batch: int = 8
    seq: int = 256
    markov_states: int = 64


class SyntheticStream:
    """Iterable over training batches; random-access by step."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 shard: int = 0, n_shards: int = 1):
        self.cfg, self.dcfg = cfg, dcfg
        self.shard, self.n_shards = shard, n_shards
        base = np.random.RandomState(dcfg.seed)
        m = dcfg.markov_states
        # sparse-ish transition structure shared by all shards
        self._trans = base.dirichlet(np.ones(m) * 0.2, size=m)
        self._emit = base.randint(0, cfg.vocab, size=m).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        d = self.dcfg
        rng = np.random.RandomState(
            (d.seed * 1_000_003 + step * 977 + self.shard) % (2 ** 31))
        b = d.batch // self.n_shards
        m = d.markov_states
        states = rng.randint(0, m, size=b)
        toks = np.empty((b, d.seq + 1), np.int32)
        for t in range(d.seq + 1):
            toks[:, t] = self._emit[states]
            u = rng.random(b)
            cdf = np.cumsum(self._trans[states], axis=1)
            states = (u[:, None] < cdf).argmax(axis=1)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "audio":
            out["frames"] = rng.randn(
                b, self.cfg.enc_frames, self.cfg.d_model).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
