"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see the
default single device).

Production target: TPU v5e pods — 16x16 = 256 chips per pod, 2 pods via
DCN for the multi-pod dry-run. Axes: ("data", "model") single-pod;
("pod", "data", "model") multi-pod, with "pod" used as an outer
data-parallel (or pipeline-stage) axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Small mesh over whatever devices this host actually has (tests)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch dimension."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def model_size(mesh) -> int:
    return mesh.shape["model"]


def batch_shard_size(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n
