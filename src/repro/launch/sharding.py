"""Sharding rules: DP (+pod) x TP/EP over the ("pod", "data", "model")
mesh, applied by parameter path.

Rules (Megatron-style):
  * embeddings shard d_model; unembed shards vocab (column-parallel with
    the loss's logsumexp all-reducing over "model");
  * attention q/k/v and MLP in-projections shard the OUT dim, o/w2 shard
    the IN dim (one all-reduce per block);
  * MoE experts shard the EXPERT axis ("model" = expert parallelism);
  * Mamba projections shard d_inner / heads / state groups;
  * anything not divisible by the model-axis size is replicated (e.g.
    whisper's 8 heads on a 16-way axis) — recorded, not fatal.

Batch dims shard over ("pod","data"). When the per-cell batch is smaller
than the data extent (long_500k: batch 1), KV/SSM caches shard the
SEQUENCE axis instead (sequence parallelism for the cache).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig
from .mesh import batch_shard_size, data_axes, model_size

PyTree = Any

# param-name -> (axis index to shard with "model"), counted AFTER any
# stacked layer axis is skipped.
_OUT_DIM = {"wq", "wk", "wv", "w1", "w3", "wz", "wx", "wB", "wC", "wdt",
            "embed", "unembed", "enc_pos", "dec_pos"}
_IN_DIM = {"wo", "w2"}
_CONV = {"conv_x", "conv_B", "conv_C"}
_REPL = {"router", "dt_bias", "A_log", "D", "gn_scale"}


def _divisible(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def param_spec(path_keys, shape, msize: int) -> P:
    """PartitionSpec for one param leaf."""
    name = path_keys[-1]
    stacked = "layers" in path_keys or "encoder" in path_keys \
        or "decoder" in path_keys
    off = 1 if stacked else 0
    spec = [None] * len(shape)
    is_moe = any(k in ("moe",) for k in path_keys) and name in (
        "w1", "w2", "w3")
    if is_moe:
        if _divisible(shape[off], msize):
            spec[off] = "model"          # expert axis
    elif name in _OUT_DIM:
        ax = len(shape) - 1
        if _divisible(shape[ax], msize):
            spec[ax] = "model"
    elif name in _IN_DIM:
        ax = off
        if _divisible(shape[ax], msize):
            spec[ax] = "model"
    elif name in _CONV:
        ax = len(shape) - 1
        if _divisible(shape[ax], msize):
            spec[ax] = "model"
    # norms / scalars / _REPL stay replicated
    return P(*spec)


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def param_specs(tree: PyTree, mesh: Mesh, plan: str = "tp") -> PyTree:
    """Parallelism plans:
      * "tp": megatron-style tensor parallel on the model axis (baseline);
      * "dp": pure data parallel — params replicated, the model axis acts
        as extra batch parallelism (right for <10B dense models where TP
        all-reduces dominate the step, see EXPERIMENTS.md Section Perf);
      * "ep": experts stay sharded on the model axis (EP), all dense
        params replicated (MoE counterpart of "dp").
    """
    msize = model_size(mesh)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    # under "ep" embeddings stay vocab/d_model-sharded: a replicated
    # unembed makes XLA split the logits matmul and then all-reduce full
    # fp32 logits (3.4 GB/microbatch on deepseek). Under "dp" the batch
    # occupies the model axis, so embeddings must NOT also use it.
    keep_tp = {"embed", "unembed", "enc_pos", "dec_pos"}
    for path, leaf in leaves:
        keys = [_path_str(p) for p in path]
        if plan == "ep" and keys[-1] in keep_tp:
            specs.append(param_spec(keys, leaf.shape, msize))
        elif plan == "dp":
            specs.append(P(*([None] * len(leaf.shape))))
        elif plan == "ep":
            is_moe = "moe" in keys and keys[-1] in ("w1", "w2", "w3")
            specs.append(param_spec(keys, leaf.shape, msize) if is_moe
                         else P(*([None] * len(leaf.shape))))
        else:
            specs.append(param_spec(keys, leaf.shape, msize))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(tree, mesh))


def zero_extend(spec: P, shape, mesh: Mesh,
                axes: Tuple[str, ...] = ("data",)) -> P:
    """ZeRO-style extension: additionally shard the first free axis over
    ``axes`` when divisible (used for optimizer state always, and for
    params under FSDP)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    flat = [a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    for combo in (axes, ("data",)):
        if any(a in flat for a in combo):
            continue
        size = 1
        for a in combo:
            size *= mesh.shape[a]
        for i, (e, n) in enumerate(zip(entries, shape)):
            if e is None and _divisible(n, size) and n >= size:
                entries[i] = combo if len(combo) > 1 else combo[0]
                return P(*entries)
    return P(*entries)


def opt_specs(param_spec_tree: PyTree, shapes: PyTree, mesh: Mesh,
              axes: Tuple[str, ...] = ("data",)) -> PyTree:
    """Specs for one Adam moment tree (mirrors params + ZeRO sharding)."""
    return jax.tree_util.tree_map(
        lambda s, x: zero_extend(s, x.shape, mesh, axes),
        param_spec_tree, shapes,
        is_leaf=lambda x: isinstance(x, P))


def fsdp_param_specs(tree: PyTree, mesh: Mesh) -> PyTree:
    base = param_specs(tree, mesh)
    return jax.tree_util.tree_map(
        lambda s, x: zero_extend(s, x.shape, mesh), base, tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, batch: int, mesh: Mesh,
                kind: str) -> PyTree:
    dp = data_axes(mesh)
    bs = batch_shard_size(mesh)
    bspec = dp if _divisible(batch, bs) else None
    if kind in ("train", "prefill"):
        out = {"tokens": P(bspec, None), "labels": P(bspec, None)}
        if cfg.family == "audio":
            out["frames"] = P(bspec, None, None)
        if kind == "prefill":
            out.pop("labels")
        return out
    return P(bspec)  # decode tokens [B]


def cache_specs(cfg: ModelConfig, batch: int, mesh: Mesh,
                cache_tree: PyTree) -> PyTree:
    """Shard KV caches: batch over data axes when divisible, otherwise the
    sequence axis (long-context decode); kv-heads / ssm-heads over model
    when divisible."""
    dp = data_axes(mesh)
    bs = batch_shard_size(mesh)
    msize = model_size(mesh)
    batch_ok = _divisible(batch, bs)

    def spec_for(path, leaf) -> P:
        keys = [_path_str(p) for p in path]
        name = keys[-1]
        shp = leaf.shape
        if name == "pos":
            return P()
        if name in ("k", "v"):          # [L, B, S, kv, hd]
            kvs = "model" if _divisible(shp[3], msize) else None
            # kv heads narrower than the model axis (llava/granite kv=8 on
            # a 16-way axis): shard the SEQUENCE axis over "model" instead
            # (split-KV decode — softmax max/sum all-reduce is tiny, and
            # it avoids all-gathering the cache, ~145 GB/step on llava)
            seq_m = None if kvs else (
                "model" if _divisible(shp[2], msize) else None)
            if batch_ok:
                return P(None, dp, seq_m, kvs, None)
            seq = "data" if _divisible(shp[2], mesh.shape["data"]) \
                else None
            if seq is not None and seq_m is not None:
                return P(None, None, ("data", "model"), kvs, None)
            return P(None, None, seq or seq_m, kvs, None)
        if name == "state":             # [L, B, H, N, P]
            hs = "model" if _divisible(shp[2], msize) else None
            return P(None, dp if batch_ok else None, hs, None, None)
        if name.startswith("conv_"):    # [L, B, K-1, W]
            ws = "model" if _divisible(shp[3], msize) else None
            return P(None, dp if batch_ok else None, None, ws)
        return P(*([None] * len(shp)))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in leaves])


def to_shardings(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
