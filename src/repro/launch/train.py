"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmo_1b --smoke \
        --steps 50 --ckpt /tmp/ck_olmo

Builds the host mesh, instantiates the fault-tolerant Trainer (auto-
resumes from --ckpt if a checkpoint exists) and runs. Full-size configs
are intended for real accelerator fleets; --smoke selects the reduced
same-family config for CPU runs.
"""
import argparse
import logging

from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(model=args.model_parallel)
    trainer = Trainer(
        cfg, mesh,
        opt_cfg=OptimizerConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps),
        tcfg=TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt,
                           ckpt_every=25, log_every=10),
        dcfg=DataConfig(batch=args.batch, seq=args.seq))
    print(trainer.run())


if __name__ == "__main__":
    main()
