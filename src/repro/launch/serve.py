"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo_1b \
        --ckpt /tmp/ck_olmo --batch 4 --new-tokens 16

Restores params from the newest checkpoint (random init without --ckpt)
and serves a batch of synthetic prompts through the Engine.
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import model_zoo
from repro.serve.engine import Engine, ServeConfig
from repro.train import checkpoint as ckpt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        res = ckpt_lib.restore(args.ckpt, {"params": jax.eval_shape(
            lambda: params)})
        if res:
            params = res[1]["params"]
            print(f"restored checkpoint step {res[0]}")
    eng = Engine(cfg, params, scfg=ServeConfig(
        max_seq=args.prompt_len + args.new_tokens + 1,
        max_new_tokens=args.new_tokens, temperature=args.temperature))
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts)
    for i, row in enumerate(out):
        print(f"seq{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
