"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite_8b \
        --shape train_4k --multi-pod

Produces experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, the collective schedule and the roofline
terms (EXPERIMENTS.md Sections Dry-run/Roofline read these files).
"""
# The host platform must present 512 placeholder devices BEFORE any jax
# import — jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, cell_status, get_config  # noqa
from repro.models import model_zoo  # noqa: E402
from repro.models.common import ModelConfig  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.mesh import (batch_shard_size, data_axes,  # noqa
                               make_production_mesh)
from repro.launch.sharding import (batch_specs, cache_specs,  # noqa
                                   fsdp_param_specs, opt_specs,
                                   param_specs, to_shardings)
from repro.roofline.analysis import (from_compiled, model_flops,  # noqa
                                     xla_cost_reference)
from repro.train.optimizer import init_opt_state  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# archs whose fp32 train state needs FSDP param sharding to fit 16 GB/chip
FSDP_ARCHS = {"llava_next_34b", "deepseek_moe_16b", "granite_8b"}
# per-device microbatch rows for grad accumulation in train_4k cells
# (n_micro = global_batch / (batch_shards * this))
PER_DEVICE_MICRO = {"llava_next_34b": 1}
DEFAULT_PER_DEVICE_MICRO = 2


def _bf16_shapes(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, jnp.bfloat16 if jnp.issubdtype(x.dtype, jnp.floating)
            else x.dtype), tree)


def _count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def _active_params(cfg: ModelConfig, tree) -> int:
    total = _count_params(tree)
    if cfg.family != "moe":
        return total
    expert = sum(
        int(x.size) for p, x in
        jax.tree_util.tree_flatten_with_path(tree)[0]
        if any(getattr(k, "key", "") == "moe" for k in p)
        and p[-1].key in ("w1", "w2", "w3"))
    return total - expert + expert * cfg.top_k // cfg.n_experts


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mesh=None, plan: str = "tp",
               capacity_factor=None, remat_policy=None) -> Dict:
    cfg = get_config(arch)
    if capacity_factor is not None:
        cfg = cfg.with_(capacity_factor=capacity_factor)
    if remat_policy is not None:
        cfg = cfg.with_(remat_policy=remat_policy)
    shape = SHAPES[shape_name]
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    nchips = mesh.devices.size
    # batch-sharding axes: "dp" plans also use the model axis for batch
    # (dropped again when the global batch doesn't divide across it)
    dp = data_axes(mesh) + (("model",) if plan == "dp" else ())
    bss = 1
    for a in dp:
        bss *= mesh.shape[a]
    if shape.global_batch % bss:
        dp = data_axes(mesh)
        bss = 1
        for a in dp:
            bss *= mesh.shape[a]
    if cfg.family == "moe":
        cfg = cfg.with_(moe_shards=bss, moe_data_axes=tuple(dp),
                        moe_expert_axis="model")
    t0 = time.time()

    pshapes = model_zoo.param_shapes(cfg)
    if shape.kind == "train":
        if plan == "tp" and arch in FSDP_ARCHS:
            pspecs = fsdp_param_specs(pshapes, mesh)
        else:
            pspecs = param_specs(pshapes, mesh, plan)
        oshapes = jax.eval_shape(init_opt_state, pshapes)
        zaxes = ("data", "model") if plan in ("dp", "ep") else ("data",)
        ospecs = {"mu": opt_specs(pspecs, pshapes, mesh, zaxes),
                  "nu": opt_specs(pspecs, pshapes, mesh, zaxes),
                  "step": P()}
        pdm = PER_DEVICE_MICRO.get(arch, DEFAULT_PER_DEVICE_MICRO)
        n_micro = max(1, shape.global_batch // (bss * pdm))
        mb = shape.global_batch // n_micro
        bshapes = {
            "tokens": jax.ShapeDtypeStruct(
                (n_micro, mb, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct(
                (n_micro, mb, shape.seq_len), jnp.int32),
        }
        bspecs = {"tokens": P(None, dp, None), "labels": P(None, dp, None)}
        if cfg.family == "audio":
            bshapes["frames"] = jax.ShapeDtypeStruct(
                (n_micro, mb, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
            bspecs["frames"] = P(None, dp, None, None)
        step = steps_lib.make_grad_accum_train_step(
            cfg, n_micro, acc_specs=to_shardings(ospecs["mu"], mesh))
        jitted = jax.jit(
            step,
            in_shardings=(to_shardings(pspecs, mesh),
                          to_shardings(ospecs, mesh),
                          to_shardings(bspecs, mesh)),
            out_shardings=(to_shardings(pspecs, mesh),
                           to_shardings(ospecs, mesh), None),
            donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(pshapes, oshapes, bshapes)
        tokens = shape.global_batch * shape.seq_len
        training = True
    elif shape.kind == "prefill":
        pshapes = _bf16_shapes(pshapes)
        pspecs = param_specs(pshapes, mesh)
        bshapes = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        bspecs = batch_specs(cfg, shape.global_batch, mesh, "prefill")
        if cfg.family == "audio":
            bshapes["frames"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.enc_frames, cfg.d_model),
                jnp.bfloat16)
        step = steps_lib.make_prefill_step(cfg, shape.seq_len)
        jitted = jax.jit(
            step,
            in_shardings=(to_shardings(pspecs, mesh),
                          to_shardings(bspecs, mesh)))
        with mesh:
            lowered = jitted.lower(pshapes, bshapes)
        tokens = shape.global_batch * shape.seq_len
        training = False
    else:  # decode
        pshapes = _bf16_shapes(pshapes)
        pspecs = param_specs(pshapes, mesh)
        cshapes = jax.eval_shape(
            lambda: model_zoo.init_cache(cfg, shape.global_batch,
                                         shape.seq_len))
        cspecs = cache_specs(cfg, shape.global_batch, mesh, cshapes)
        tshapes = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        tspecs = batch_specs(cfg, shape.global_batch, mesh, "decode")
        step = steps_lib.make_decode_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(to_shardings(pspecs, mesh),
                          to_shardings(cspecs, mesh),
                          NamedSharding(mesh, tspecs)),
            out_shardings=(None, to_shardings(cspecs, mesh)),
            donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(pshapes, cshapes, tshapes)
        tokens = shape.global_batch  # one token per sequence
        training = False

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    # The CPU backend emulates bf16 dots by converting operands to f32;
    # those hoisted whole-tensor converts don't exist on TPU (native bf16
    # MXU). Quantify them so the TPU peak estimate is visible.
    f32_hoist = 0
    import re as _re
    for line in compiled.as_text().splitlines():
        m = _re.match(r"\s*(?:ROOT )?%[\w.\-]+ = f32\[([\d,]+)\][^=]*"
                      r" convert\(", line)
        if m:
            n = 1
            for d in m.group(1).split(","):
                n *= int(d)
            if n * 4 >= 2 ** 28:
                f32_hoist += n * 4
    rl, colls = from_compiled(compiled, nchips)
    n_params = _count_params(pshapes)
    n_active = _active_params(cfg, pshapes)
    mf = model_flops(n_params, tokens, n_active, training)
    # embedding params don't contribute matmul FLOPs; ratio is indicative
    useful = mf / max(rl.flops * nchips, 1.0) if rl.flops else 0.0

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": nchips,
        "kind": shape.kind,
        "n_params": n_params, "n_active_params": n_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "output_bytes_per_device": getattr(
                mem, "output_size_in_bytes", 0),
            "temp_bytes_per_device": getattr(
                mem, "temp_size_in_bytes", 0),
            "argument_bytes_per_device": getattr(
                mem, "argument_size_in_bytes", 0),
            "peak_bytes_per_device": (
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)),
            "cpu_f32_dot_emulation_bytes": f32_hoist,
            "tpu_peak_estimate_bytes": max(
                0, getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0) - f32_hoist),
        },
        "roofline": rl.as_dict(),
        "collectives": {"counts": colls.counts,
                        "bytes": colls.bytes_by_kind},
        "xla_cost_reference": xla_cost_reference(compiled),
        "model_flops": mf,
        "useful_flops_ratio": useful,
    }
    return result


def run_and_save(arch: str, shape_name: str, multi_pod: bool,
                 out_dir: str, mesh=None, plan: str = "tp",
                 capacity_factor=None, remat_policy=None) -> Optional[Dict]:
    ok, why = cell_status(arch, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if plan == "tp" else f"__{plan}"
    path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": why}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[skip] {arch} {shape_name} {mesh_name}: {why}")
        return rec
    try:
        rec = lower_cell(arch, shape_name, multi_pod, mesh=mesh,
                         plan=plan, capacity_factor=capacity_factor,
                         remat_policy=remat_policy)
        rec["status"] = "ok"
        rec["plan"] = plan
    except Exception as e:  # a failing cell is a bug — surface it loudly
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": f"FAIL: {e}",
               "traceback": traceback.format_exc()}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] == "ok":
        r = rec["roofline"]
        print(f"[ok]   {arch:22s} {shape_name:12s} {mesh_name:8s} "
              f"compile={rec['compile_s']:6.1f}s "
              f"peak={rec['memory']['peak_bytes_per_device']/2**30:6.2f}"
              f"GiB (tpu~"
              f"{rec['memory']['tpu_peak_estimate_bytes']/2**30:.2f}) "
              f"bottleneck={r['bottleneck']:10s} "
              f"(c={r['compute_s']:.3e} m={r['memory_s']:.3e} "
              f"coll={r['collective_s']:.3e})")
    else:
        print(f"[FAIL] {arch} {shape_name} {mesh_name}: "
              f"{rec['status'][:200]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--plan", default="tp", choices=["tp", "dp", "ep"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--remat-policy", default=None,
                    choices=["full", "dots", "mlp"])
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = []
    if args.multi_pod or args.all:
        pods.append(True)
    if args.single_pod or args.all or not pods:
        pods.insert(0, False)

    failures = 0
    meshes = {mp: make_production_mesh(multi_pod=mp) for mp in pods}
    for mp in pods:
        for a in archs:
            for s in shapes:
                rec = run_and_save(a, s, mp, args.out, mesh=meshes[mp],
                                   plan=args.plan,
                                   capacity_factor=args.capacity_factor,
                                   remat_policy=args.remat_policy)
                if rec and str(rec.get("status", "")).startswith("FAIL"):
                    failures += 1
    print(f"\ndry-run complete; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
