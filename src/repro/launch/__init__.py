"""launch subpackage."""
