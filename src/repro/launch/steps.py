"""jit-able step functions per (config, shape kind)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model_zoo
from repro.models.common import ModelConfig
from repro.train.optimizer import OptimizerConfig, adamw_update, \
    init_opt_state

PyTree = Any


def make_train_step(cfg: ModelConfig,
                    opt_cfg: Optional[OptimizerConfig] = None):
    opt_cfg = opt_cfg or OptimizerConfig()

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model_zoo.loss_fn(cfg, p, batch),
            has_aux=True)(params)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def make_grad_accum_train_step(cfg: ModelConfig, n_micro: int,
                               opt_cfg: Optional[OptimizerConfig] = None,
                               acc_specs: Optional[PyTree] = None):
    """Gradient accumulation over ``n_micro`` microbatches (scan) — the
    backward of microbatch i overlaps XLA-scheduled collectives of i-1.

    ``acc_specs`` (a PartitionSpec tree mirroring params) pins the fp32
    accumulator's sharding — without it XLA may replicate the accumulator
    across the model axis (observed 162 GiB/device on deepseek_moe_16b).
    """
    opt_cfg = opt_cfg or OptimizerConfig()

    def train_step(params, opt_state, batch):
        # batch leaves are [n_micro, b/n_micro, ...]
        def constrain(tree):
            if acc_specs is None:
                return tree
            return jax.tree_util.tree_map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s),
                tree, acc_specs)

        def micro(carry, mb):
            gsum, lsum = carry
            (loss, _), grads = jax.value_and_grad(
                lambda p: model_zoo.loss_fn(cfg, p, mb),
                has_aux=True)(params)
            gsum = constrain(jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads))
            return (gsum, lsum + loss), None

        zeros = constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), batch)
        grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        return params, opt_state, {"loss": lsum / n_micro, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch):
        if cfg.family == "audio":
            return model_zoo.prefill(cfg, params, batch["tokens"],
                                     max_seq, frames=batch["frames"])
        return model_zoo.prefill(cfg, params, batch["tokens"], max_seq)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens):
        return model_zoo.decode_step(cfg, params, cache, tokens)
    return serve_step


def opt_state_shapes(cfg: ModelConfig) -> PyTree:
    pshapes = model_zoo.param_shapes(cfg)
    return jax.eval_shape(init_opt_state, pshapes)
