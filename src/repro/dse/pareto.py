"""Incremental Pareto frontier with dominance pruning.

All objectives are minimized. The frontier is maintained incrementally:
``add`` rejects dominated candidates in one pass over the current frontier
and evicts any incumbents the new point dominates, so the structure is
always exactly the non-dominated set of everything offered so far.
Duplicate-objective points are kept only once (first writer wins), which
makes resumed sweeps idempotent.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_OBJECTIVES = ("total_ns", "energy_pj", "area_mm2")


@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    key: str                        # DesignPoint.key()
    objectives: Tuple[float, ...]   # aligned with frontier.names
    payload: Optional[Dict] = None  # full evaluation record


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """``a`` dominates ``b``: <= everywhere, < somewhere (minimization)."""
    strict = False
    for x, y in zip(a, b):
        if x > y:
            return False
        if x < y:
            strict = True
    return strict


class ParetoFrontier:
    """The non-dominated set under per-name minimization objectives."""

    def __init__(self, names: Sequence[str] = DEFAULT_OBJECTIVES):
        self.names: Tuple[str, ...] = tuple(names)
        self._points: List[FrontierPoint] = []

    def __len__(self) -> int:
        return len(self._points)

    def __iter__(self):
        return iter(self.points)

    @property
    def points(self) -> List[FrontierPoint]:
        """Frontier sorted by the first objective."""
        return sorted(self._points, key=lambda p: p.objectives)

    def key_set(self) -> set:
        """Keys of the current frontier (O(F); membership tests O(1))."""
        return {p.key for p in self._points}

    def objectives_of(self, record: Dict) -> Tuple[float, ...]:
        """This frontier's objective vector of an evaluation record."""
        return tuple(float(record[n]) for n in self.names)

    def add_record(self, key: str, record: Dict) -> bool:
        """``add`` with objectives pulled out of an evaluation record."""
        return self.add(key, self.objectives_of(record), record)

    def add(self, key: str, objectives: Sequence[float],
            payload: Optional[Dict] = None) -> bool:
        """Offer a point; returns True iff it joins the frontier.

        Dominated candidates are rejected; incumbents dominated by the
        candidate are evicted. A candidate with exactly the objectives of
        an incumbent is redundant and rejected (idempotent resume)."""
        objs = tuple(float(v) for v in objectives)
        if len(objs) != len(self.names):
            raise ValueError(
                f"expected {len(self.names)} objectives, got {len(objs)}")
        for p in self._points:
            if p.objectives == objs or dominates(p.objectives, objs):
                return False
        self._points = [p for p in self._points
                        if not dominates(objs, p.objectives)]
        self._points.append(FrontierPoint(key, objs, payload))
        return True

    def dominated(self, objectives: Sequence[float]) -> bool:
        """True iff the frontier already dominates (or equals) the
        given objective vector — ``add`` would reject it."""
        objs = tuple(float(v) for v in objectives)
        return any(dominates(p.objectives, objs) or p.objectives == objs
                   for p in self._points)

    def best(self, name: str) -> Optional[FrontierPoint]:
        """Frontier point minimizing one named objective."""
        if not self._points:
            return None
        i = self.names.index(name)
        return min(self._points, key=lambda p: p.objectives[i])

    def canonical_json(self) -> str:
        """Canonical serialization for byte-comparing frontiers across
        runs and worker counts (payloads carry wall-clock noise and are
        excluded; key + objectives are the frontier's identity). Exact
        duplicate objective vectors are rejected on ``add``, so sorting
        by (objectives, key) is a total order."""
        pts = sorted(self._points, key=lambda p: (p.objectives, p.key))
        return json.dumps(
            {"names": list(self.names),
             "points": [{"key": p.key, "objectives": list(p.objectives)}
                        for p in pts]},
            sort_keys=True, separators=(",", ":"))
