"""Architecture design-space exploration (DSE) on the batched engine.

Co-searches PIM architecture configurations (``core.arch`` factories)
jointly with overlap-driven mapping search: the NicePIM/PIMSYN-style
"best (arch, mapping) pair" capability on top of Fast-OverlaPIM's fast
overlap analysis. See DESIGN.md Section 8.
"""
from .distrib import (DistribConfig, run_coordinator, run_distributed,
                      worker_loop)
from .driver import (execute_sweep, frontier_points, journal_path_for,
                     journal_template, network_token, objective_tag,
                     shared_dir_for, sweep_summary)
from .explore import (DSEConfig, DSEResult, EXPLORERS, ProposalStream,
                      evaluate_point, network_energy_pj, point_key,
                      proposal_stream, record_edp, run_dse)
from .pareto import (DEFAULT_OBJECTIVES, FrontierPoint, ParetoFrontier,
                     dominates)
from .persist import (FileBackend, JournalBackend, RunJournal,
                      SharedDirBackend, content_key)
from .report import (best_arch_table, frontier_table, summarize,
                     sweep_networks)
from .space import (DesignPoint, ParamSpace, SPACES, dram_space, get_space,
                    reram_space, tpu_space)

__all__ = [n for n in dir() if not n.startswith("_")]
