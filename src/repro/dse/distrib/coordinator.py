"""Work-stealing coordinator: distribute one sweep over many workers.

The coordinator owns exactly what the serial ``run_dse`` owns — the
seed-deterministic proposal stream — and *only* that. For every
generation the stream proposes, it

1. content-keys each point (``key_for`` over the built arch),
2. refreshes the merged shared-dir journal and drops every key already
   present (resumed and overlapping sweeps dispatch zero redundant
   mapping searches),
3. partitions the misses, in proposal order, into content-keyed batches
   (``batch_id`` = SHA-1 over the member keys, so a re-posted batch in a
   crashed-and-restarted sweep collides with its previous done marker
   instead of duplicating work) and publishes their manifests,
4. waits until the merged journal holds every key of the generation —
   workers claim batches under expiring leases, so a crashed worker's
   batch is re-stolen by a peer rather than wedging the sweep — then
5. feeds the generation's records, in proposal order, back into the
   stream and repeats.

Because the stream advances only on merged-journal records and every
evaluation is deterministic and content-keyed, N workers produce the
same record sequence — and therefore the byte-identical Pareto
frontier — as one worker or the serial path (differentially tested in
``tests/test_dse_distrib.py``; DESIGN.md Section 10).

Worker placement is orthogonal: ``worker_mode="process"`` forks local
worker processes (the ``--distributed N`` CLI), ``"thread"`` runs them
in-process (tests, and sweeps whose cost is outside the GIL),
``"external"`` spawns none and waits for ``dse-worker`` processes —
possibly on other machines sharing the directory — to show up.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from typing import Dict, List, Optional, Sequence

from ... import obs
from ...obs import merge_snapshots, quantile
from ..explore import (DSEConfig, DSEResult, ProposalStream, key_for,
                       proposal_stream)
from ..pareto import ParetoFrontier
from ..persist import RunJournal, SharedDirBackend
from ..space import ParamSpace, get_space
from .lease import clear_stop, post_manifest, read_json, request_stop
from .worker import WorkerConfig, metrics_dir, worker_entry, worker_loop

WORKER_MODES = ("process", "thread", "external")


def clear_metrics(root: str) -> None:
    """Drop metrics shards a previous sweep left in a reused shared dir
    (coordinator start-up — mirrors ``clear_stop``), so the end-of-sweep
    fleet summary covers exactly this sweep's workers."""
    mdir = metrics_dir(root)
    try:
        names = os.listdir(mdir)
    except FileNotFoundError:
        return
    for n in names:
        if n.endswith(".json"):
            try:
                os.remove(os.path.join(mdir, n))
            except FileNotFoundError:
                pass


def collect_fleet(root: str) -> Optional[Dict]:
    """Merge every worker's metrics shard under ``<root>/metrics/`` into
    the coordinator's fleet-health view.

    Returns ``{"summary": ..., "snapshot": ...}`` — the summary sums the
    workers' loop counters (batches, evaluated, lease claims/steals/
    expiries, dedup skips) and adds batch-evaluate latency percentiles;
    the snapshot is the element-wise metrics merge, ready for
    ``obs.render_report``. None when no worker published a shard."""
    mdir = metrics_dir(root)
    try:
        names = sorted(os.listdir(mdir))
    except FileNotFoundError:
        return None
    shards = []
    for n in names:
        if n.endswith(".json"):
            body = read_json(os.path.join(mdir, n))
            if body is not None:
                shards.append(body)
    if not shards:
        return None
    snap = merge_snapshots([s.get("snapshot") or {} for s in shards])
    totals: Dict[str, float] = {}
    for s in shards:
        for k, v in (s.get("stats") or {}).items():
            totals[k] = totals.get(k, 0) + v
    summary: Dict = {"workers_reported": len(shards)}
    summary.update({k: int(v) for k, v in sorted(totals.items())})
    h = (snap.get("histograms") or {}).get("fleet.batch_eval_seconds")
    if h and h.get("count"):
        summary["batch_eval_p50_s"] = quantile(h["bounds"], h["counts"],
                                               0.50)
        summary["batch_eval_p99_s"] = quantile(h["bounds"], h["counts"],
                                               0.99)
        summary["batch_eval_mean_s"] = h["sum"] / h["count"]
    snap["gauges"]["fleet.workers"] = float(len(shards))
    return {"summary": summary, "snapshot": snap}


@dataclasses.dataclass
class DistribConfig:
    """How one sweep is spread over workers (the *what* lives in
    ``DSEConfig``). ``batch_size`` trades scheduling granularity against
    lease traffic; 1 maximizes load balance on small sweeps."""

    root: str
    n_workers: int = 2
    batch_size: int = 1
    lease_ttl_s: float = 60.0
    poll_s: float = 0.02
    timeout_s: float = 3600.0
    worker_mode: str = "process"
    # cap on concurrently *active* local workers; the default (0)
    # resolves to cpu_count. Oversubscribed hosts (n_workers > cores)
    # timeslice the same cores at a large scheduling cost — and surplus
    # workers' polling traffic competes with productive compute — so
    # surplus workers block on a shared semaphore until a slot frees
    # (with an acquire timeout: a crashed holder degrades the fleet to
    # slow polling, never deadlock). None disables the gate. External
    # workers (other machines) are never gated — they have their own
    # CPUs.
    compute_slots: Optional[int] = 0

    def __post_init__(self):
        assert self.worker_mode in WORKER_MODES, self.worker_mode
        assert self.batch_size >= 1, "batch_size must be >= 1"
        assert self.n_workers >= 0, "n_workers must be >= 0"

    def resolved_slots(self) -> Optional[int]:
        """Effective compute-gate width (None = gate can never bind)."""
        slots = self.compute_slots
        if slots == 0:
            slots = os.cpu_count() or 1
        if slots is not None and slots >= self.n_workers:
            return None   # gate can never bind: skip the semaphore
        return slots


def batch_id_for(keys: Sequence[str]) -> str:
    """Content key of a work batch: the SHA-1 of its member keys."""
    return hashlib.sha1(",".join(keys).encode()).hexdigest()[:20]


def _spawn_workers(dist: DistribConfig) -> List:
    """Start the requested local workers; external mode starts none."""
    handles: List = []
    if dist.worker_mode == "external" or dist.n_workers == 0:
        return handles
    slots = dist.resolved_slots()
    if dist.worker_mode == "thread":
        import threading
        gate = None if slots is None else threading.Semaphore(slots)
        for i in range(dist.n_workers):
            t = threading.Thread(
                target=worker_loop,
                args=(WorkerConfig(root=dist.root, worker_id=f"thread-{i}",
                                   poll_s=dist.poll_s,
                                   lease_ttl_s=dist.lease_ttl_s,
                                   compute_gate=gate),),
                daemon=True)
            t.start()
            handles.append(t)
        return handles
    import multiprocessing
    try:                       # fork shares the warmed interpreter
        ctx = multiprocessing.get_context("fork")
    except ValueError:         # pragma: no cover - non-POSIX fallback
        ctx = multiprocessing.get_context("spawn")
    gate = None if slots is None else ctx.Semaphore(slots)
    for _ in range(dist.n_workers):
        p = ctx.Process(target=worker_entry,
                        args=(dist.root, dist.lease_ttl_s, dist.poll_s,
                              None, gate),
                        daemon=True)
        p.start()
        handles.append(p)
    return handles


def _workers_alive(handles: List) -> int:
    return sum(1 for h in handles if h.is_alive())


def _join_workers(handles: List, timeout_s: float = 30.0) -> None:
    deadline = time.monotonic() + timeout_s
    for h in handles:
        h.join(timeout=max(0.0, deadline - time.monotonic()))
    for h in handles:
        if h.is_alive() and hasattr(h, "terminate"):
            h.terminate()


def _wait_for_keys(journal: RunJournal, keys: Sequence[str],
                   dist: DistribConfig, handles: List) -> None:
    """Block until the merged journal holds every key of the generation.

    Progress is the workers' job (including re-stealing expired leases);
    the coordinator only detects the two unrecoverable states: every
    local worker died, or the timeout lapsed."""
    deadline = time.monotonic() + dist.timeout_s
    while True:
        journal.refresh()
        missing = [k for k in keys if k not in journal]
        if not missing:
            return
        if handles and dist.worker_mode != "external" \
                and _workers_alive(handles) == 0:
            raise RuntimeError(
                f"all {len(handles)} workers exited with "
                f"{len(missing)} evaluations outstanding")
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"distributed sweep timed out ({dist.timeout_s:.0f}s) "
                f"with {len(missing)} evaluations outstanding; "
                f"first missing key: {missing[0]}")
        time.sleep(dist.poll_s)


def run_distributed(dcfg: DSEConfig, dist: DistribConfig,
                    space: Optional[ParamSpace] = None) -> DSEResult:
    """Run one sweep over the shared directory; same result contract as
    ``run_dse`` (records in proposal order, baseline first)."""
    space = space or get_space(dcfg.family)
    os.makedirs(dist.root, exist_ok=True)
    clear_stop(dist.root)      # a finished sweep leaves STOP behind
    clear_metrics(dist.root)   # ... and its workers' metrics shards
    backend = SharedDirBackend(dist.root, writer_id="coordinator")
    journal = RunJournal(backend=backend)
    stream: ProposalStream = proposal_stream(space, dcfg)
    frontier = ParetoFrontier()
    records: List[Dict] = []
    n_dispatched = 0
    n_from_journal = 0
    n_batches = 0
    t0 = time.perf_counter()
    handles = _spawn_workers(dist)
    try:
        while True:
            batch = stream.next_batch()
            if batch is None:
                break
            built = [space.build(p) for p in batch]
            keys = [key_for(dcfg, a.to_key()) for a in built]
            journal.refresh()
            miss = [i for i, k in enumerate(keys) if k not in journal]
            n_from_journal += len(batch) - len(miss)
            n_dispatched += len(miss)
            for lo in range(0, len(miss), dist.batch_size):
                chunk = miss[lo:lo + dist.batch_size]
                bkeys = [keys[i] for i in chunk]
                post_manifest(dist.root, {
                    "batch_id": batch_id_for(bkeys),
                    "dcfg": dataclasses.asdict(dcfg),
                    "items": [{"key": keys[i],
                               "family": batch[i].family,
                               "point": batch[i].as_dict(),
                               "arch": built[i].to_dict()}
                              for i in chunk],
                })
                n_batches += 1
            _wait_for_keys(journal, keys, dist, handles)
            recs = [journal.get(k) for k in keys]
            for p, rec in zip(batch, recs):
                records.append(rec)
                frontier.add_record(p.key(), rec)
            stream.observe(batch, recs)
    finally:
        request_stop(dist.root)
        _join_workers(handles)
    stats = {
        "proposed": len(records),
        "evaluated": n_dispatched,
        "from_journal": n_from_journal,
        "frontier": len(frontier),
        "wall_s": time.perf_counter() - t0,
        "workers": dist.n_workers,
        "batches": n_batches,
    }
    # fold the workers' metrics shards into the end-of-sweep summary
    # (previously the workers computed these counters and dropped them)
    fleet = collect_fleet(dist.root)
    if fleet is not None:
        stats["fleet"] = fleet["summary"]
        reg = obs.registry()
        if reg is not None:
            reg.merge_snapshot(fleet["snapshot"])
    return DSEResult(config=dcfg, records=records, frontier=frontier,
                     baseline=records[0], stats=stats)


def run_coordinator(dcfg: DSEConfig, dist: DistribConfig,
                    space: Optional[ParamSpace] = None) -> DSEResult:
    """``dse-coordinator`` entry: drive the sweep, spawn no workers —
    external ``dse-worker`` processes (any machine sharing the
    directory) supply the compute."""
    dist = dataclasses.replace(dist, worker_mode="external", n_workers=0)
    return run_distributed(dcfg, dist, space=space)
