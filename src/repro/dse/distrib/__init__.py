"""Distributed sweep subsystem: shared-journal work-stealing DSE.

Many workers — threads, local processes, or ``dse-worker`` processes on
other machines — share one sweep through a plain directory: record
shards (``persist.SharedDirBackend``), batch manifests, and expiring
leases (``lease.LeaseBoard``). The coordinator drives the same pure
proposal streams as the serial path, so N workers reproduce the
1-worker Pareto frontier bit-exactly. See DESIGN.md Section 10.
"""
from .coordinator import (DistribConfig, WORKER_MODES, batch_id_for,
                          run_coordinator, run_distributed)
from .lease import (LeaseBoard, atomic_write_json, clear_stop,
                    list_manifests, post_manifest, read_json,
                    request_stop, stop_requested)
from .worker import (WorkerConfig, dcfg_from_manifest,
                     evaluate_manifest_item, worker_entry, worker_loop)

__all__ = [n for n in dir() if not n.startswith("_")]
