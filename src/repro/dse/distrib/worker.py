"""Distributed sweep worker: claim batches, evaluate, publish.

A worker is stateless with respect to the sweep: everything it needs is
in the shared directory. It polls ``batches/`` for manifests, skips any
whose keys are already all in the merged journal (marking them done so
nobody else bothers), claims the rest through the ``LeaseBoard`` —
stealing expired leases of crashed peers — evaluates each point with a
long-lived per-worker ``OverlapEngine`` (per-arch cache bundles evicted
after scoring, so memory stays bounded across an arbitrarily long
sweep), publishes the records as one atomic shard, and marks the batch
done. It exits when the coordinator posts ``STOP`` (or after
``max_idle_s`` without work, for fire-and-forget deployments).

Manifests carry *built* ``ArchSpec`` dicts, never ``ParamSpace``s — the
same rule as the PR-2 process pool: spaces can hold unpicklable
constraint lambdas, and rebuilding one worker-side could silently
diverge from the caller's. A worker therefore never needs the space at
all, which is what lets ``dse-worker`` processes on other machines join
a sweep knowing nothing but the shared directory.
"""
from __future__ import annotations

import dataclasses
import os
import time
import uuid
from typing import Dict, Optional

from ...core.arch import ArchSpec
from ...core.engine import OverlapEngine
from ...obs import Registry
from ..explore import DSEConfig, _make_record, _search_arch
from ..persist import RunJournal, SharedDirBackend
from ..space import DesignPoint
from .lease import LeaseBoard, ManifestCache, atomic_write_json, stop_token

METRICS_DIRNAME = "metrics"


def metrics_dir(root: str) -> str:
    """The shared-dir subdirectory holding per-worker metrics shards."""
    return os.path.join(root, METRICS_DIRNAME)


def write_metrics_shard(root: str, worker_id: str, stats: Dict,
                        registry: Registry) -> str:
    """Publish one worker's metrics shard (atomic rename) into
    ``<root>/metrics/<worker_id>.json``: the loop counters plus a full
    registry snapshot. The worker uses a *worker-local* registry — never
    the process-global one — so thread-mode fleets (coordinator workers
    in one process) cannot double-count when the coordinator merges the
    shards back into a fleet summary."""
    path = os.path.join(metrics_dir(root), f"{worker_id}.json")
    atomic_write_json(path, {"worker": worker_id, "stats": stats,
                             "snapshot": registry.snapshot()})
    return path


@dataclasses.dataclass
class WorkerConfig:
    root: str
    worker_id: Optional[str] = None
    poll_s: float = 0.05
    lease_ttl_s: float = 60.0
    # exit after this long with no claimable work even without STOP
    # (None = run until the coordinator says stop)
    max_idle_s: Optional[float] = None
    # optional semaphore bounding concurrently *active* local workers:
    # when a host runs more workers than cores, letting every process
    # compute at once just timeslices the same cores at a large
    # scheduling cost — and on sandboxed filesystems even the surplus
    # workers' polling competes with the productive ones' compute, so
    # the whole scan-claim-evaluate iteration is gated and the surplus
    # blocks on the semaphore (a kernel wait, not a poll). Acquisition
    # uses a timeout, so a crashed gate-holder degrades the fleet to
    # slow polling instead of deadlocking it, and STOP is still seen.
    compute_gate: Optional[object] = None

    def resolved_id(self) -> str:
        """The configured worker id, or a fresh pid-random one."""
        return self.worker_id or f"w{os.getpid()}-{uuid.uuid4().hex[:6]}"


def dcfg_from_manifest(man: Dict) -> DSEConfig:
    """The manifest's sweep config, sanitized for worker-side scoring:
    a distributed worker is itself the unit of parallelism (no nested
    process pool) and journals through the shared dir, not a file."""
    d = dict(man["dcfg"])
    d["workers"] = 0
    d["journal_path"] = None
    return DSEConfig(**d)


def evaluate_manifest_item(item: Dict, dcfg: DSEConfig,
                           engine: Optional[OverlapEngine]) -> Dict:
    """One full mapping search for one manifest item — bit-identical to
    the serial evaluator's record for the same content key."""
    arch = ArchSpec.from_dict(item["arch"])
    point = DesignPoint.make(item["family"], item["point"])
    fields = _search_arch(arch, dcfg, engine=engine)
    if engine is not None:
        # scored once per sweep: evict the bundle to bound worker memory
        engine.evict_arch(arch)
    return _make_record(point, dcfg, arch, fields)


def worker_loop(wcfg: WorkerConfig) -> Dict[str, int]:
    """Run until STOP (or ``max_idle_s``); returns counters for tests
    and the ``dse-worker`` CLI: batches completed, points evaluated,
    expired leases stolen, batches skipped because the merged journal
    already had every key."""
    wid = wcfg.resolved_id()
    backend = SharedDirBackend(wcfg.root, writer_id=wid)
    journal = RunJournal(backend=backend)
    board = LeaseBoard(wcfg.root, wid, ttl_s=wcfg.lease_ttl_s)
    manifest_cache = ManifestCache(wcfg.root)
    engine = OverlapEngine()
    # worker-LOCAL registry: fleet metrics flow only through the shard
    # this worker publishes at exit (see ``write_metrics_shard``)
    reg = Registry()
    stats = {"batches": 0, "evaluated": 0, "stolen": 0,
             "skipped_done": 0}
    idle_since = time.monotonic()
    sleep_s = wcfg.poll_s
    gate = wcfg.compute_gate
    # a STOP left behind by a previous sweep on a reused directory is
    # stale: only a *different* token (the coordinator clears STOP at
    # start and re-posts with a fresh one) means this sweep is over
    stale_stop = stop_token(wcfg.root)

    def stopped() -> bool:
        tok = stop_token(wcfg.root)
        return tok is not None and tok != stale_stop

    gate_failures = 0
    while True:
        acquired = True
        if gate is not None:
            acquired = gate.acquire(timeout=0.2)
            if not acquired:
                gate_failures += 1
                if stopped():
                    break
                if gate_failures < 50:
                    continue  # no slot: block again, touch no shared files
                # ~10s without a slot: every holder may have crashed
                # (a dead process never releases its semaphore slot).
                # Proceed ungated at this degraded cadence so expired
                # leases still get re-stolen — liveness beats the
                # oversubscription guard.
                gate_failures = 0
        try:
            progressed = _work_pass(wcfg, board, manifest_cache, journal,
                                    engine, stats, reg)
        finally:
            if gate is not None and acquired:
                gate.release()
        stats["stolen"] = board.n_stolen
        now = time.monotonic()
        if progressed:
            idle_since = now
            sleep_s = wcfg.poll_s
            continue
        if stopped():
            break
        if wcfg.max_idle_s is not None \
                and now - idle_since > wcfg.max_idle_s:
            break
        time.sleep(sleep_s)
        # idle backoff: a worker with nothing claimable must not flood
        # the shared filesystem while its peers compute
        sleep_s = min(sleep_s * 1.5, max(wcfg.poll_s, 0.25))
    stats["claims"] = board.n_claims
    stats["expired"] = board.n_expired
    for k in ("batches", "evaluated", "stolen", "skipped_done",
              "claims", "expired"):
        if stats[k]:
            reg.counter("fleet." + k).inc(stats[k])
    engine.publish_metrics(registry=reg)
    write_metrics_shard(wcfg.root, wid, stats, reg)
    return stats


def _work_pass(wcfg: WorkerConfig, board: LeaseBoard,
               manifest_cache: ManifestCache, journal: RunJournal,
               engine: OverlapEngine, stats: Dict[str, int],
               reg: Optional[Registry] = None) -> bool:
    """One scan over the published manifests; returns True if anything
    was completed (evaluated or dedup-marked done)."""
    progressed = False
    manifests = manifest_cache.scan()
    if manifests:
        # one merge per scan pass (shards are immutable, so this is
        # O(new shards)); per-item dedup below is then dict lookups
        journal.refresh()
    for man in manifests:
        bid = man["batch_id"]
        if board.is_done(bid):
            continue
        # dedup against the merged journal before doing any work:
        # a resumed or overlapping sweep must cost zero searches
        todo = [it for it in man["items"] if it["key"] not in journal]
        if not todo:
            board.mark_done(bid, {"n_evaluated": 0, "deduped": True})
            stats["skipped_done"] += 1
            progressed = True
            continue
        if not board.try_claim(bid):
            continue
        try:
            # claimed: re-merge once — a peer may have published
            # some of these keys between the scan and the claim
            journal.refresh()
            todo = [it for it in todo if it["key"] not in journal]
            if not todo:
                board.mark_done(bid, {"n_evaluated": 0, "deduped": True})
                stats["skipped_done"] += 1
                progressed = True
                continue
            dcfg = dcfg_from_manifest(man)
            stolen_midway = False
            n_done = 0
            t_batch = time.perf_counter()
            for it in todo:
                rec = evaluate_manifest_item(it, dcfg, engine)
                journal.record(it["key"], rec)
                stats["evaluated"] += 1
                n_done += 1
                # still alive on long batches; a False renewal means the
                # lease expired and a peer stole the batch — back off
                # and let the thief finish it (our records publish
                # anyway; the merge dedups)
                if not board.renew(bid):
                    stolen_midway = True
                    break
            if reg is not None and n_done:
                reg.histogram("fleet.batch_eval_seconds").observe(
                    time.perf_counter() - t_batch)
            journal.publish()          # one atomic shard per batch
            if not stolen_midway:
                board.mark_done(bid, {"n_evaluated": n_done})
                stats["batches"] += 1
        finally:
            board.release(bid)
        progressed = True
    return progressed


def worker_entry(root: str, lease_ttl_s: float = 60.0,
                 poll_s: float = 0.05,
                 max_idle_s: Optional[float] = None,
                 compute_gate: Optional[object] = None) -> Dict[str, int]:
    """Plain-args entry point (multiprocessing / CLI)."""
    return worker_loop(WorkerConfig(root=root, lease_ttl_s=lease_ttl_s,
                                    poll_s=poll_s, max_idle_s=max_idle_s,
                                    compute_gate=compute_gate))
