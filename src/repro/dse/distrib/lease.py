"""File-based work-stealing primitives for the distributed sweep.

Everything here speaks plain directory-on-a-shared-filesystem (the same
substrate ``SharedDirBackend`` uses for records), so "a cluster" can be
N processes on one box, N boxes on NFS, or a fuse-mounted bucket — no
coordinator RPC, no daemon. Layout under the sweep root::

    batches/<batch_id>.json   work manifests (atomic-rename published)
    leases/<batch_id>.json    live claims  {worker, expires_at}
    done/<batch_id>.json      completion markers
    STOP                      coordinator -> workers: sweep over

The safety story is built from two POSIX guarantees:

* ``O_CREAT | O_EXCL`` — exactly one worker wins a fresh lease.
* ``os.replace`` is atomic — manifests/markers are never seen partially
  written, and *stealing* an expired lease is a rename race that exactly
  one thief can win (everyone else gets ``FileNotFoundError``).

Leases carry a wall-clock expiry. A worker renews its lease after every
point it evaluates; if a worker dies mid-batch its lease stops being
renewed, expires, and any other worker steals the batch and re-evaluates
it from scratch (unpublished work is lost by design — evaluations are
deterministic and content-keyed, so a re-run is bit-identical and the
merged journal deduplicates).
"""
from __future__ import annotations

import json
import os
import time
import uuid
from typing import Dict, List, Optional

STOP_NAME = "STOP"


def atomic_write_json(path: str, obj: Dict) -> None:
    """Publish a JSON file readers can never observe half-written."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp-{uuid.uuid4().hex[:8]}"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(obj, fh, sort_keys=True)
        fh.flush()
    os.replace(tmp, path)


def read_json(path: str) -> Optional[Dict]:
    """Best-effort read: None for missing or (transiently) unparsable."""
    try:
        with open(path, "r", encoding="utf-8") as fh:
            obj = json.load(fh)
    except (FileNotFoundError, json.JSONDecodeError, OSError):
        return None
    return obj if isinstance(obj, dict) else None


def request_stop(root: str) -> None:
    """Post the STOP marker. The body carries a fresh token so workers
    can tell *this* sweep's STOP from a stale one a previous sweep left
    behind in a reused directory (see ``stop_token``)."""
    atomic_write_json(os.path.join(root, STOP_NAME),
                      {"stop": True, "token": uuid.uuid4().hex})


def clear_stop(root: str) -> None:
    """Remove a previous sweep's STOP marker (coordinator start-up)."""
    try:
        os.remove(os.path.join(root, STOP_NAME))
    except FileNotFoundError:
        pass


def stop_token(root: str) -> Optional[str]:
    """The current STOP marker's token (None if no STOP is posted).
    A worker snapshots this at startup and treats only a *different*
    token as a live stop request: a stale STOP from a finished sweep on
    a reused directory must not make an early-started worker exit
    before its coordinator even arrives (the coordinator clears and
    re-posts STOP with a fresh token)."""
    body = read_json(os.path.join(root, STOP_NAME))
    if body is None:
        return None
    return str(body.get("token", "legacy"))


def stop_requested(root: str) -> bool:
    """True iff a STOP marker exists (any token — callers who must
    distinguish sweeps compare the token themselves)."""
    return os.path.exists(os.path.join(root, STOP_NAME))


def post_manifest(root: str, manifest: Dict) -> str:
    """Publish one batch manifest; returns its batch id."""
    bid = manifest["batch_id"]
    atomic_write_json(os.path.join(root, "batches", f"{bid}.json"),
                      manifest)
    return bid


def list_manifests(root: str) -> List[Dict]:
    """All published manifests, in sorted-name (= deterministic) order."""
    bdir = os.path.join(root, "batches")
    try:
        names = sorted(os.listdir(bdir))
    except FileNotFoundError:
        return []
    out = []
    for n in names:
        if not n.endswith(".json"):
            continue
        m = read_json(os.path.join(bdir, n))
        if m is not None and "batch_id" in m:
            out.append(m)
    return out


class ManifestCache:
    """Incremental manifest reader for worker poll loops.

    Manifests are immutable once published (atomic rename, never
    rewritten), so each file needs reading exactly once; a poll is then
    one ``listdir`` plus reads of only the *new* names. Without this,
    N idle workers re-reading every manifest each poll turn the shared
    filesystem into the sweep's bottleneck."""

    def __init__(self, root: str):
        self._dir = os.path.join(root, "batches")
        self._by_name: Dict[str, Dict] = {}

    def scan(self) -> List[Dict]:
        """All manifests, sorted by name; immutable ones are read at
        most once and served from the cache afterwards."""
        try:
            names = sorted(os.listdir(self._dir))
        except FileNotFoundError:
            return []
        for n in names:
            if n.endswith(".json") and n not in self._by_name:
                m = read_json(os.path.join(self._dir, n))
                if m is not None and "batch_id" in m:
                    self._by_name[n] = m
        return [self._by_name[n] for n in names if n in self._by_name]


class LeaseBoard:
    """Claim / renew / steal / complete batches for one worker identity."""

    def __init__(self, root: str, worker_id: str,
                 ttl_s: float = 60.0):
        self.root = root
        self.worker_id = worker_id
        self.ttl_s = ttl_s
        #: plain counters, harvested into per-worker metrics shards by
        #: ``worker_loop`` (no process-global telemetry here — thread-mode
        #: workers would double-count a shared registry)
        self.n_stolen = 0
        self.n_claims = 0
        self.n_expired = 0
        # done markers are write-once: cache positives, re-check misses
        self._done_cache: set = set()
        os.makedirs(os.path.join(root, "leases"), exist_ok=True)
        os.makedirs(os.path.join(root, "done"), exist_ok=True)

    def _lease_path(self, batch_id: str) -> str:
        return os.path.join(self.root, "leases", f"{batch_id}.json")

    def _done_path(self, batch_id: str) -> str:
        return os.path.join(self.root, "done", f"{batch_id}.json")

    def is_done(self, batch_id: str) -> bool:
        """True once the batch has a write-once done marker (cached —
        done markers never disappear)."""
        if batch_id in self._done_cache:
            return True
        if os.path.exists(self._done_path(batch_id)):
            self._done_cache.add(batch_id)
            return True
        return False

    def read_lease(self, batch_id: str) -> Optional[Dict]:
        """The batch's current lease body, or None if unclaimed."""
        return read_json(self._lease_path(batch_id))

    def try_claim(self, batch_id: str) -> bool:
        """Claim the batch, stealing an expired lease if one is in the
        way. Returns True iff this worker now holds the lease."""
        if self.is_done(batch_id):
            return False
        path = self._lease_path(batch_id)
        cur = read_json(path)
        if cur is not None:
            if cur.get("expires_at", 0.0) > time.time():
                return False       # live lease held by someone else
            self.n_expired += 1
            # expired: exactly one thief wins this rename
            tomb = f"{path}.stolen-{uuid.uuid4().hex[:8]}"
            try:
                os.replace(path, tomb)
            except FileNotFoundError:
                return False       # raced: released or already stolen
            try:
                os.remove(tomb)
            except FileNotFoundError:
                pass
            self.n_stolen += 1
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False           # raced: someone re-claimed first
        with os.fdopen(fd, "w", encoding="utf-8") as fh:
            json.dump(self._lease_body(), fh)
            fh.flush()
        self.n_claims += 1
        return True

    def _owns(self, batch_id: str) -> bool:
        cur = read_json(self._lease_path(batch_id))
        return cur is not None and cur.get("worker") == self.worker_id

    def renew(self, batch_id: str) -> bool:
        """Push the expiry out; called after every evaluated point so a
        *live* worker on a long batch is never mistaken for a dead one.
        Ownership is re-checked first, so a holder whose lease expired
        and was stolen mid-point almost always sees the thief's lease
        and backs off (returns False). The check is best-effort, not
        atomic with the write — a steal landing in between leaves two
        workers believing they hold the batch. That costs duplicate
        mapping searches, never correctness: evaluations are
        deterministic and the journal merge dedups by content key."""
        if not self._owns(batch_id):
            return False
        atomic_write_json(self._lease_path(batch_id), self._lease_body())
        return True

    def release(self, batch_id: str) -> None:
        """Drop the lease — only if still ours (see ``renew``)."""
        if not self._owns(batch_id):
            return
        try:
            os.remove(self._lease_path(batch_id))
        except FileNotFoundError:
            pass

    def mark_done(self, batch_id: str, meta: Optional[Dict] = None) -> None:
        """Write the batch's done marker (write-once; atomic rename)."""
        body = {"worker": self.worker_id}
        if meta:
            body.update(meta)
        atomic_write_json(self._done_path(batch_id), body)

    def _lease_body(self) -> Dict:
        return {"worker": self.worker_id,
                "expires_at": time.time() + self.ttl_s}
