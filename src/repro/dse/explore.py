"""DSE explorers: grid / random / evolutionary search over arch spaces.

Each explorer proposes ``DesignPoint``s and scores them by running the
full overlap-driven mapping search (``optimize_network`` with the batched
engine) for the configured network/mode/strategy. Scoring goes through one
shared funnel (``_Evaluator``) that

* serves already-scored points from the ``RunJournal`` (content-keyed —
  re-running a finished sweep performs **zero** new mapping searches),
* in serial mode shares a single ``OverlapEngine`` across all arch points
  (per-arch cache bundles, see ``core.engine``; a point's bundle is
  evicted once scored — each arch is visited once per sweep — while the
  engine's content-keyed ``PerfCache`` persists), and
* with ``workers > 0`` fans evaluations out to a process pool. Workers
  receive the *built* ``ArchSpec`` (``to_dict`` round-trip), never the
  ``ParamSpace`` — custom spaces carry unpicklable constraint lambdas,
  and rebuilding a shipped space in the worker would silently diverge
  from a caller-supplied one. Each worker keeps a persistent engine;
  results are bit-identical to serial mode (differentially tested).

All explorers are deterministic in ``DSEConfig.seed``: the same config
proposes the same points in the same order (the evolutionary explorer
selects on journal-identical scores), which is what makes journal resume
exact rather than best-effort.

Proposal generation itself is a pure stream (``proposal_stream`` /
``ProposalStream``): generations are proposed through ``next_batch()``
and advanced only by ``observe()``d records, so *how* a generation got
scored — serial, process pool, or N distributed workers over a shared
journal (``repro.dse.distrib``) — cannot influence what is proposed
next. The distributed coordinator drives exactly these streams.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .. import obs
from ..core.arch import ArchSpec
from ..core.engine import OverlapEngine, optimize_network_engine
from ..core.perf_model import arch_area_proxy, arch_power_proxy
from ..core.interface import describe
from ..core.search import (MODES, OBJECTIVES, STRATEGIES, NetworkResult,
                           SearchConfig, combine_objective)
from .pareto import ParetoFrontier
from .persist import RunJournal, content_key
from .space import DesignPoint, ParamSpace, get_space

EXPLORERS = ("grid", "random", "evolve")


@dataclasses.dataclass
class DSEConfig:
    """One sweep: which space to search, how, and how each point is
    scored. ``budget`` counts *proposed* points (journal hits included —
    a resumed sweep proposes the same points and evaluates none)."""

    family: str = "dram_pim"
    network: str = "resnet18"
    mode: str = "transform"
    strategy: str = "forward"
    explorer: str = "evolve"
    budget: int = 64
    seed: int = 1
    # per-point mapping-search budget
    n_candidates: int = 8
    max_steps: int = 2048
    refine_passes: int = 0
    # mapping-search objective (core.search.OBJECTIVES); non-latency
    # objectives get distinct journal keys and drive the evolutionary
    # explorer's fitness through the record's ``objective_value``
    objective: str = "latency"
    blend_alpha: float = 0.5
    # evolutionary knobs
    population: int = 8
    mutation_rate: float = 0.5
    # evaluation backend
    workers: int = 0              # 0 = serial, shared engine
    journal_path: Optional[str] = None

    def __post_init__(self):
        assert self.mode in MODES, self.mode
        assert self.strategy in STRATEGIES, self.strategy
        assert self.explorer in EXPLORERS, self.explorer
        assert self.objective in OBJECTIVES, self.objective
        assert 0.0 <= self.blend_alpha <= 1.0, \
            f"blend_alpha must be in [0, 1], got {self.blend_alpha}"
        assert self.budget >= 1, "budget must be >= 1"

    def search_config(self) -> SearchConfig:
        """The per-point mapping-search config (always engine-backed)."""
        return SearchConfig(n_candidates=self.n_candidates, seed=self.seed,
                            max_steps=self.max_steps, mode=self.mode,
                            strategy=self.strategy,
                            refine_passes=self.refine_passes,
                            use_engine=True, objective=self.objective,
                            blend_alpha=self.blend_alpha)

    def objective_token(self) -> str:
        """Journal-key token: "blend" depends on its alpha too."""
        if self.objective == "blend":
            return f"blend:{self.blend_alpha!r}"
        return self.objective


@dataclasses.dataclass
class DSEResult:
    config: DSEConfig
    records: List[Dict]                  # proposal order
    frontier: ParetoFrontier
    baseline: Dict                       # the space's default point
    stats: Dict[str, float]

    def best_within_area(self, area_mm2: Optional[float] = None) \
            -> Optional[Dict]:
        """Lowest-latency record with area proxy <= the given budget
        (default: the baseline's area) — the iso-area comparison."""
        cap = self.baseline["area_mm2"] if area_mm2 is None else area_mm2
        eligible = [r for r in self.records if r["area_mm2"] <= cap + 1e-12]
        return min(eligible, key=lambda r: r["total_ns"], default=None)

    def best_by(self, metric: str = "edp_ns_pj") -> Optional[Dict]:
        """Record minimizing one recorded metric. ``edp_ns_pj`` tolerates
        pre-energy journal records (``record_edp``)."""
        def val(r: Dict) -> float:
            if metric == "edp_ns_pj":
                return record_edp(r)
            return r[metric]
        return min(self.records, key=val, default=None)


# ---------------------------------------------------------------------------
# Point evaluation (one full mapping search).
# ---------------------------------------------------------------------------

def key_for(dcfg: DSEConfig, arch_key: str) -> str:
    """THE journal-key derivation — every scoring-relevant ``DSEConfig``
    field must appear here (and only here), or resumed sweeps would
    silently serve stale scores for changed evaluations."""
    return content_key(dcfg.network, dcfg.mode, dcfg.strategy, dcfg.seed,
                       dcfg.n_candidates, dcfg.max_steps,
                       dcfg.refine_passes, arch_key,
                       objective=dcfg.objective_token())


def point_key(space: ParamSpace, point: DesignPoint,
              dcfg: DSEConfig) -> str:
    """Journal key of one design point under one sweep config
    (``key_for`` over the built ``ArchSpec``'s content key)."""
    return key_for(dcfg, space.build(point).to_key())


def record_edp(rec: Dict) -> float:
    """THE energy-delay product of an evaluation record — every report
    and BENCH entry goes through here. Pre-energy journal records lack
    the ``edp_ns_pj`` column; it is recomputed from what they do carry."""
    if "edp_ns_pj" in rec:
        return rec["edp_ns_pj"]
    return rec["total_ns"] * rec["energy_pj"]


def network_energy_pj(result: NetworkResult) -> float:
    """Mapping-level network energy: base (compute + IO) plus the
    movement energy of transform-relocated tiles."""
    return float(sum(l.energy_pj for l in result.layers))


def _search_arch(arch, dcfg: DSEConfig,
                 engine: Optional[OverlapEngine] = None) -> Dict:
    """The mapping-search half of an evaluation (runs in workers too)."""
    desc = describe(dcfg.network)
    t0 = time.perf_counter()
    res = optimize_network_engine(desc.layers, desc.edges, arch,
                                  dcfg.search_config(), engine=engine)
    total_ns = float(res.total_ns)
    energy = network_energy_pj(res)
    return {
        "total_ns": total_ns,
        "energy_pj": energy,
        "move_energy_pj": float(sum(l.move_energy_pj
                                    for l in res.layers)),
        "edp_ns_pj": total_ns * energy,
        "n_layers": len(res.layers),
        "wall_s": time.perf_counter() - t0,
    }


def _make_record(point: DesignPoint, dcfg: DSEConfig,
                 arch: ArchSpec, search_fields: Dict) -> Dict:
    costs = {"area_mm2": arch_area_proxy(arch),
             "power_w": arch_power_proxy(arch)}
    return {
        "family": point.family,
        "point": point.as_dict(),
        "point_key": point.key(),
        "arch_name": arch.name,
        "network": dcfg.network,
        "mode": dcfg.mode,
        "strategy": dcfg.strategy,
        "seed": dcfg.seed,
        "n_candidates": dcfg.n_candidates,
        "max_steps": dcfg.max_steps,
        "objective": dcfg.objective,
        "objective_value": combine_objective(
            dcfg.objective, search_fields["total_ns"],
            search_fields["energy_pj"], dcfg.blend_alpha),
        "area_mm2": costs["area_mm2"],
        "power_w": costs["power_w"],
        **search_fields,
    }


def evaluate_point(space: ParamSpace, point: DesignPoint, dcfg: DSEConfig,
                   engine: Optional[OverlapEngine] = None) -> Dict:
    """Score one design point: build the arch, run the mapping search,
    attach the static cost proxies."""
    arch = space.build(point)
    return _make_record(point, dcfg, arch,
                        _search_arch(arch, dcfg, engine))


# Process-pool worker state: one engine per worker process, reused across
# every point that worker evaluates. Workers receive the *built*
# ``ArchSpec`` (via to_dict), never the ParamSpace: custom spaces carry
# unpicklable constraint lambdas, and rebuilding a shipped space in the
# worker would silently diverge from a caller-supplied one.
_WORKER_ENGINE: Optional[OverlapEngine] = None


def _pool_eval(payload: Tuple[Dict, Dict]) -> Dict:
    global _WORKER_ENGINE
    if _WORKER_ENGINE is None:
        _WORKER_ENGINE = OverlapEngine()
    dcfg_dict, arch_dict = payload
    dcfg = DSEConfig(**dcfg_dict)
    arch = ArchSpec.from_dict(arch_dict)
    fields = _search_arch(arch, dcfg, engine=_WORKER_ENGINE)
    # each arch point is scored once per sweep (explorers dedup, the
    # journal absorbs revisits) — evict its bundle to bound worker memory
    _WORKER_ENGINE.evict_arch(arch)
    return fields


class _Evaluator:
    """Journal-aware batch scorer (serial shared engine or process pool).

    ``engine`` may be caller-supplied (the mapping service shares ONE
    engine across requests so repeat arch families resume warm caches);
    then bundle *retention* is the caller's policy — the per-point
    ``evict_arch`` that bounds a one-shot sweep's memory is skipped, and
    the caller trims with ``OverlapEngine.evict_lru`` between sweeps."""

    def __init__(self, space: ParamSpace, dcfg: DSEConfig,
                 journal: RunJournal,
                 engine: Optional[OverlapEngine] = None):
        self.space = space
        self.dcfg = dcfg
        self.journal = journal
        self.engine = engine if engine is not None else OverlapEngine()
        self._evict_after_score = engine is None
        self.n_evaluated = 0
        self.n_from_journal = 0
        self._pool = None
        if dcfg.workers > 0:
            import concurrent.futures
            self._pool = concurrent.futures.ProcessPoolExecutor(
                max_workers=dcfg.workers)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None
        self.engine.publish_metrics()

    def __call__(self, points: Sequence[DesignPoint]) -> List[Dict]:
        """Scores in point order; journal hits cost nothing."""
        built = [self.space.build(p) for p in points]
        keys = [key_for(self.dcfg, a.to_key()) for a in built]
        out: List[Optional[Dict]] = [self.journal.get(k) for k in keys]
        misses = [i for i, r in enumerate(out) if r is None]
        self.n_from_journal += len(points) - len(misses)
        obs.inc("dse.proposed", len(points))
        obs.inc("dse.journal_hits", len(points) - len(misses))
        if misses:
            archs = [built[i] for i in misses]
            with obs.span("dse.evaluate_batch", n=len(misses),
                          network=self.dcfg.network, mode=self.dcfg.mode):
                if self._pool is not None:
                    dd = dataclasses.asdict(self.dcfg)
                    fields = list(self._pool.map(
                        _pool_eval, [(dd, a.to_dict()) for a in archs]))
                else:
                    fields = []
                    for a in archs:
                        fields.append(_search_arch(a, self.dcfg,
                                                   engine=self.engine))
                        # scored once per sweep: evict to bound memory
                        # while the engine's PerfCache keeps cross-arch
                        # reuse (shared engines retain — caller's policy)
                        if self._evict_after_score:
                            self.engine.evict_arch(a)
            for i, a, f in zip(misses, archs, fields):
                rec = _make_record(points[i], self.dcfg, a, f)
                out[i] = self.journal.record(keys[i], rec)
                obs.observe("dse.eval_seconds", f["wall_s"])
            self.n_evaluated += len(misses)
            obs.inc("dse.evaluated", len(misses))
            # no-op for file journals; shard-publish for shared-dir ones
            self.journal.publish()
        return out  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Proposal streams. Proposal generation is a *pure, seed-deterministic
# stream* decoupled from evaluation: next_batch() yields the next
# generation of fresh points, observe() feeds their scored records back
# in batch order — the ONLY channel through which evaluation influences
# later proposals. Identical observed records => identical proposal
# sequence, no matter who (or how many distributed workers) produced
# them; that is the distributed-sweep determinism argument (DESIGN.md
# Section 10): N workers reproduce the 1-worker frontier bit-exactly.
# ---------------------------------------------------------------------------

class ProposalStream:
    """Alternating ``next_batch()`` / ``observe()`` proposal protocol.

    ``next_batch`` returns the next generation of fresh, deduplicated
    ``DesignPoint``s (``None`` once the budget is spent or the space is
    exhausted); ``observe`` must then be called with the scored records
    of exactly that batch, in batch order, before the next generation
    can be proposed."""

    def __init__(self, space: ParamSpace, dcfg: DSEConfig):
        self.space = space
        self.dcfg = dcfg
        self.n_proposed = 0
        self._awaiting = False

    def next_batch(self) -> Optional[List[DesignPoint]]:
        """Propose the next generation (``None`` = stream exhausted)."""
        assert not self._awaiting, \
            "observe() the previous batch before proposing the next"
        batch = self._propose()
        if not batch:
            return None
        self.n_proposed += len(batch)
        self._awaiting = True
        return batch

    def observe(self, points: Sequence[DesignPoint],
                records: Sequence[Dict]) -> None:
        """Feed back the scored records of the pending batch, in batch
        order — the only channel from evaluation to later proposals."""
        assert self._awaiting, "observe() without a pending batch"
        assert len(points) == len(records)
        self._awaiting = False
        self._digest(points, records)

    def _propose(self) -> List[DesignPoint]:
        raise NotImplementedError

    def _digest(self, points: Sequence[DesignPoint],
                records: Sequence[Dict]) -> None:
        pass  # grid/random ignore scores


class _OneShotStream(ProposalStream):
    """grid/random: the whole proposal list is known upfront."""

    def __init__(self, space: ParamSpace, dcfg: DSEConfig,
                 points: List[DesignPoint]):
        super().__init__(space, dcfg)
        self._points = points

    def _propose(self) -> List[DesignPoint]:
        pts, self._points = self._points, []
        return pts


def _grid_list(space: ParamSpace, dcfg: DSEConfig) -> List[DesignPoint]:
    """Default point first (the baseline), then grid order."""
    out, seen = [space.default()], {space.default().key()}
    for p in space.enumerate():
        if len(out) >= dcfg.budget:
            break
        if p.key() not in seen:
            seen.add(p.key())
            out.append(p)
    return out


def _random_list(space: ParamSpace, dcfg: DSEConfig) -> List[DesignPoint]:
    rng = random.Random(dcfg.seed)
    out, seen = [space.default()], {space.default().key()}
    tries = 0
    while len(out) < dcfg.budget and tries < dcfg.budget * 64:
        p = space.sample(rng)
        tries += 1
        if p.key() not in seen:
            seen.add(p.key())
            out.append(p)
    return out


class _EvolveStream(ProposalStream):
    """(mu + lambda)-style evolution over arch genes.

    Generation 0 is the default point plus random samples. Parents are
    tournament-selected with Pareto-frontier membership beating raw
    latency; children are per-gene crossover then (p=mutation_rate) an
    adjacent-value mutation. Proposals are deduplicated against
    everything seen, so the budget is spent on distinct points. State
    advances exclusively through ``observe``d records — in a distributed
    sweep those come from the *merged* journal, so every worker count
    sees the same scores and the rng consumes the same sequence."""

    def __init__(self, space: ParamSpace, dcfg: DSEConfig):
        super().__init__(space, dcfg)
        self.rng = random.Random(dcfg.seed ^ 0x9E3779B9)
        self.pop_size = max(2, min(dcfg.population, dcfg.budget))
        self.seen: set = set()
        self.pool: List[Tuple[DesignPoint, Dict]] = []
        self.frontier = ParetoFrontier()
        self.front_keys: set = set()   # refreshed once per generation

    def _fitness(self, entry: Tuple[DesignPoint, Dict]) -> Tuple[int, float]:
        # frontier membership first, then the sweep's scoring objective
        # (pre-energy journal records lack objective_value; they can only
        # have been produced by a latency sweep, where it == total_ns)
        p, rec = entry
        return (0 if rec["point_key"] in self.front_keys else 1,
                rec.get("objective_value", rec["total_ns"]))

    def _select(self) -> DesignPoint:
        a, b = self.rng.choice(self.pool), self.rng.choice(self.pool)
        return min((a, b), key=self._fitness)[0]

    def _propose(self) -> List[DesignPoint]:
        if self.n_proposed == 0:
            init = [self.space.default()]
            self.seen.add(init[0].key())
            tries = 0
            while len(init) < self.pop_size and tries < self.pop_size * 64:
                p = self.space.sample(self.rng)
                tries += 1
                if p.key() not in self.seen:
                    self.seen.add(p.key())
                    init.append(p)
            return init[:self.dcfg.budget]
        batch: List[DesignPoint] = []
        attempts = 0
        want = min(self.pop_size, self.dcfg.budget - self.n_proposed)
        while len(batch) < want and attempts < want * 64:
            attempts += 1
            child = self.space.crossover(self._select(), self._select(),
                                         self.rng)
            if self.rng.random() < self.dcfg.mutation_rate:
                child = self.space.mutate(child, self.rng)
            if child.key() in self.seen:
                child = self.space.mutate(child, self.rng)
            if child.key() in self.seen:
                continue
            self.seen.add(child.key())
            batch.append(child)
        return batch  # empty => space exhausted => stream ends

    def _digest(self, points: Sequence[DesignPoint],
                records: Sequence[Dict]) -> None:
        for p, rec in zip(points, records):
            self.frontier.add_record(p.key(), rec)
        if not self.pool:          # generation 0: seed the parent pool
            self.pool = list(zip(points, records))
            self.front_keys = self.frontier.key_set()
            return
        self.front_keys = self.frontier.key_set()
        self.pool.extend(zip(points, records))
        self.pool.sort(key=self._fitness)
        del self.pool[max(self.pop_size, 2):]


def proposal_stream(space: ParamSpace, dcfg: DSEConfig) -> ProposalStream:
    """THE explorer factory — serial ``run_dse`` and the distributed
    coordinator drive the same streams, which is what makes them agree."""
    if dcfg.explorer == "grid":
        return _OneShotStream(space, dcfg, _grid_list(space, dcfg))
    if dcfg.explorer == "random":
        return _OneShotStream(space, dcfg, _random_list(space, dcfg))
    return _EvolveStream(space, dcfg)


def run_dse(dcfg: DSEConfig, space: Optional[ParamSpace] = None,
            journal: Optional[RunJournal] = None,
            deadline_s: Optional[float] = None,
            engine: Optional[OverlapEngine] = None) -> DSEResult:
    """Run one sweep; returns records, the Pareto frontier and stats.

    The space default point is always proposed first, so every result
    carries a baseline for iso-area comparisons.

    ``engine`` shares a caller-owned ``OverlapEngine`` across sweeps
    (bundle retention is then the caller's policy — see ``_Evaluator``);
    results are bit-identical either way, since every cache is
    content-keyed. Serial-only (``workers == 0``): the process pool
    keeps its per-worker engines.

    ``deadline_s`` bounds the sweep's wall clock: scoring switches to
    point-at-a-time and stops once the deadline passes, returning the
    best-so-far frontier (``stats["deadline_hit"]`` is then True). The
    baseline is always scored, deadline or not, so the result contract
    holds. Because proposal and evaluation order are deterministic, a
    deadline only truncates a deterministic evaluation sequence — and
    journal hits are near-free, so a warm re-request replays the prefix
    instantly and spends its deadline entirely on new points."""
    space = space or get_space(dcfg.family)
    journal = journal if journal is not None \
        else RunJournal(dcfg.journal_path)
    ev = _Evaluator(space, dcfg, journal, engine=engine)
    frontier = ParetoFrontier()
    records: List[Dict] = []
    t0 = time.perf_counter()
    deadline_hit = False

    def expired() -> bool:
        return (deadline_s is not None
                and time.perf_counter() - t0 >= deadline_s)

    sweep_span = obs.span("dse.sweep", family=dcfg.family,
                          network=dcfg.network, explorer=dcfg.explorer,
                          budget=dcfg.budget)
    sweep_span.__enter__()
    try:
        stream = proposal_stream(space, dcfg)
        while True:
            # at least one point (the baseline) is always scored
            if records and expired():
                deadline_hit = True
                break
            batch = stream.next_batch()
            if batch is None:
                break
            if deadline_s is None:
                recs = ev(batch)
            else:
                recs = []
                for p in batch:
                    recs.append(ev([p])[0])
                    if len(recs) < len(batch) and expired():
                        deadline_hit = True
                        break
            for p, rec in zip(batch, recs):
                records.append(rec)
                frontier.add_record(p.key(), rec)
            if deadline_hit:
                break   # partial batch: the stream is never observe()d
            stream.observe(batch, recs)
    finally:
        ev.close()
        sweep_span.__exit__(None, None, None)
    baseline = records[0]
    stats = {
        "proposed": len(records),
        "evaluated": ev.n_evaluated,
        "from_journal": ev.n_from_journal,
        "frontier": len(frontier),
        "wall_s": time.perf_counter() - t0,
        "deadline_hit": deadline_hit,
    }
    return DSEResult(config=dcfg, records=records, frontier=frontier,
                     baseline=baseline, stats=stats)
