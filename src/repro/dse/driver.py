"""Sweep driver: the library face of ``benchmarks/run.py dse``.

Everything that used to live between ``argparse`` and ``print`` in the
CLI — journal naming, serial-vs-distributed dispatch, and the
machine-readable sweep summary — lives here, so the CLI, the
benchmarks, and the mapping service (``repro.serve.service``) drive
sweeps through one code path and can never disagree on where a journal
lives or what a summary means.

``execute_sweep`` is the single entry point: it runs ``run_dse``
serially (optionally under a wall-clock deadline) or fans the same
config out through the distributed subsystem (``repro.dse.distrib``),
returning the same ``DSEResult`` contract either way.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, Optional

from .explore import DSEConfig, DSEResult, record_edp, run_dse
from .persist import RunJournal
from .space import ParamSpace

#: default directory for CLI/service journals (relative to the cwd)
JOURNAL_ROOT = "dse_runs"


def objective_tag(objective: str, blend_alpha: float = 0.5) -> str:
    """Filename/BENCH-key token of a sweep objective.

    Empty for ``latency`` (the implicit objective of every pre-energy
    journal, so their paths stay stable); ``blend`` carries its alpha so
    differently-weighted sweeps never share a journal or a BENCH entry.
    """
    if objective == "latency":
        return ""
    if objective == "blend":
        return f"blend{blend_alpha:g}"
    return objective


def journal_template(family: str, objective: str = "latency",
                     blend_alpha: float = 0.5,
                     root: str = JOURNAL_ROOT) -> str:
    """THE journal-path template: ``<root>/<family>_{network}_{mode}
    [_<objective>].jsonl``. A caller-supplied literal path simply has no
    placeholders and formats to itself."""
    tag = objective_tag(objective, blend_alpha)
    return os.path.join(
        root, family + "_{network}_{mode}" + (f"_{tag}" if tag else "")
        + ".jsonl")


def network_token(network: str) -> str:
    """Filesystem token of a network/scenario name: the zoo scenario
    grammar's ``:``/``@`` (``deepseek_moe_16b:prefill@2048``) and any
    other shell-hostile character become ``-``. Identity for the core
    network names, so their journal paths are unchanged."""
    return re.sub(r"[^A-Za-z0-9_.\-]", "-", network)


def journal_path_for(cfg: DSEConfig, root: str = JOURNAL_ROOT) -> str:
    """Resolved journal path of one sweep (``cfg.journal_path`` wins if
    set; otherwise the shared naming scheme)."""
    template = cfg.journal_path or journal_template(
        cfg.family, cfg.objective, cfg.blend_alpha, root)
    return template.format(network=network_token(cfg.network),
                           mode=cfg.mode)


def shared_dir_for(journal_path: str) -> str:
    """Default distributed shared-dir of a journal path: ``.jsonl`` ->
    ``.shared`` (a sibling directory, so the two stores sit together)."""
    if journal_path.endswith(".jsonl"):
        return journal_path[:-len(".jsonl")] + ".shared"
    return journal_path + ".shared"


def execute_sweep(cfg: DSEConfig, *,
                  space: Optional[ParamSpace] = None,
                  journal: Optional[RunJournal] = None,
                  deadline_s: Optional[float] = None,
                  engine=None,
                  distributed: int = 0,
                  shared_dir: Optional[str] = None,
                  batch_size: int = 1,
                  lease_ttl_s: float = 60.0,
                  timeout_s: float = 3600.0) -> DSEResult:
    """Run one sweep — serial or distributed — under one contract.

    Serial (``distributed == 0``): ``run_dse`` with an optional
    wall-clock ``deadline_s`` (best-so-far frontier on expiry) and an
    optional caller-owned shared ``OverlapEngine`` (the mapping
    service's cross-request cache warming).
    Distributed (``distributed == N > 0``): the shared-dir work-stealing
    subsystem with N local worker processes; ``shared_dir`` defaults to
    the sweep's journal path with ``.jsonl`` -> ``.shared``. Deadlines
    and caller-supplied journals/spaces/engines are serial-only (workers
    build their own view from the shared directory; spaces and engines
    do not pickle).
    """
    if distributed <= 0:
        return run_dse(cfg, space=space, journal=journal,
                       deadline_s=deadline_s, engine=engine)
    if deadline_s is not None:
        raise ValueError("deadline_s is serial-only; a distributed "
                         "sweep runs to completion of its budget")
    if space is not None or journal is not None or engine is not None:
        raise ValueError("distributed sweeps derive space, journal and "
                         "engines from the config/shared dir; pass none")
    from .distrib import DistribConfig, run_distributed
    root = shared_dir or shared_dir_for(journal_path_for(cfg))
    dist = DistribConfig(root=root, n_workers=distributed,
                         batch_size=batch_size, lease_ttl_s=lease_ttl_s,
                         timeout_s=timeout_s)
    return run_distributed(dataclasses.replace(cfg, journal_path=None),
                           dist)


def sweep_summary(res: DSEResult) -> Dict:
    """Machine-readable summary of one sweep — THE schema behind
    ``BENCH_search.json["dse"]`` entries and service responses: stats,
    baseline, iso-area and EDP winners, and the full frontier with the
    EDP-dominance flag against the latency-only baseline."""
    best = res.best_within_area() or res.baseline
    best_edp = res.best_by("edp_ns_pj") or res.baseline
    return {
        "explorer": res.config.explorer,
        "objective": res.config.objective,
        "blend_alpha": res.config.blend_alpha,
        "budget": res.config.budget,
        "evaluated": res.stats["evaluated"],
        "from_journal": res.stats["from_journal"],
        "frontier": res.stats["frontier"],
        "wall_s": round(res.stats["wall_s"], 2),
        "baseline_arch": res.baseline["arch_name"],
        "baseline_total_ns": res.baseline["total_ns"],
        "baseline_energy_pj": res.baseline["energy_pj"],
        "baseline_edp_ns_pj": record_edp(res.baseline),
        "best_iso_area_arch": best["arch_name"],
        "best_iso_area_total_ns": best["total_ns"],
        "best_iso_area_point": best["point"],
        "best_edp_arch": best_edp["arch_name"],
        "best_edp_ns_pj": record_edp(best_edp),
        "best_edp_total_ns": best_edp["total_ns"],
        "best_edp_energy_pj": best_edp["energy_pj"],
        # True iff some frontier point beats the latency-only search
        # on the default arch (the baseline) on EDP
        "frontier_dominates_baseline_on_edp": any(
            p.objectives[0] * p.objectives[1] < record_edp(res.baseline)
            for p in res.frontier.points),
        # the energy-aware frontier itself (latency/energy/area all
        # minimized), so BENCH_search.json records the trade-off
        "frontier_points": frontier_points(res),
    }


def frontier_points(res: DSEResult) -> list:
    """The frontier as plain dicts (latency/energy/area plus the arch
    identity), the wire format of summaries and service responses."""
    return [
        {"arch_name": (p.payload or {}).get("arch_name", p.key),
         "point": (p.payload or {}).get("point"),
         "total_ns": p.objectives[0],
         "energy_pj": p.objectives[1],
         "area_mm2": p.objectives[2],
         "move_energy_pj": (p.payload or {}).get("move_energy_pj"),
         "edp_ns_pj": p.objectives[0] * p.objectives[1]}
        for p in res.frontier.points]
