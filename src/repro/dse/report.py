"""Human-readable DSE reports: frontier tables and best-arch summaries.

``frontier_table`` renders one sweep's Pareto frontier; ``summarize``
prints sweep stats, the baseline (the space's default architecture — for
``dram_pim`` that is the paper's 2-channel x 8-bank config) and the
iso-area winner. ``sweep_networks`` is the multi-network driver behind
``benchmarks/run.py dse --network all``: one frontier per (network, mode)
plus a cross-network best-arch table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

from .explore import DSEConfig, DSEResult, record_edp, run_dse
from .pareto import ParetoFrontier


def _fmt_point(params: Dict) -> str:
    return " ".join(f"{k}={v}" for k, v in sorted(params.items()))


def _table(headers: Sequence[str], rows: List[Sequence[str]]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths)).rstrip()
    out = [line(headers), line(["-" * w for w in widths])]
    out += [line(r) for r in rows]
    return "\n".join(out)


def frontier_table(frontier: ParetoFrontier) -> str:
    """The non-dominated set, best latency first.

    Both energy columns use the same pJ -> J conversion (1e12 pJ/J):
    ``energy_J`` is the full mapping-level energy (compute + IO + tile
    movement), ``move_energy_J`` the transform-relocation share of it
    (absent in pre-energy journal records, shown as ``-``)."""
    rows = []
    for p in frontier.points:
        rec = p.payload or {}
        move_pj = rec.get("move_energy_pj")
        rows.append((
            rec.get("arch_name", p.key),
            f"{p.objectives[0] / 1e6:.3f}",
            f"{p.objectives[1] / 1e12:.1f}",
            "-" if move_pj is None else f"{move_pj / 1e12:.2e}",
            f"{p.objectives[2]:.2f}",
            f"{rec.get('power_w', float('nan')):.2f}",
            _fmt_point(rec.get("point", {})),
        ))
    return _table(("arch", "latency_ms", "energy_J", "move_energy_J",
                   "area_mm2", "power_W", "point"), rows)


def summarize(result: DSEResult) -> str:
    """Stats + baseline-vs-best lines for one sweep."""
    st, base = result.stats, result.baseline
    c = result.config
    lines = [
        f"dse: family={c.family} network={c.network} mode={c.mode} "
        f"strategy={c.strategy} explorer={c.explorer} "
        f"objective={c.objective}",
        f"dse: proposed={st['proposed']} evaluated={st['evaluated']} "
        f"from_journal={st['from_journal']} frontier={st['frontier']} "
        f"wall_s={st['wall_s']:.1f}",
        f"dse: baseline {base['arch_name']} "
        f"latency_ms={base['total_ns'] / 1e6:.3f} "
        f"energy_J={base['energy_pj'] / 1e12:.1f} "
        f"area_mm2={base['area_mm2']:.2f}",
    ]
    best_edp = result.best_by("edp_ns_pj")
    if best_edp is not None:
        edp = record_edp(best_edp)
        lines.append(
            f"dse: best-EDP {best_edp['arch_name']} edp={edp:.4e} "
            f"latency_ms={best_edp['total_ns'] / 1e6:.3f} "
            f"energy_J={best_edp['energy_pj'] / 1e12:.1f}")
    best = result.best_within_area()
    if best is not None and best is not result.baseline:
        speedup = base["total_ns"] / best["total_ns"]
        lines.append(
            f"dse: best@iso-area {best['arch_name']} "
            f"latency_ms={best['total_ns'] / 1e6:.3f} "
            f"area_mm2={best['area_mm2']:.2f} speedup={speedup:.2f}x "
            f"({_fmt_point(best['point'])})")
        lines.append(
            "dse: improved=" +
            ("True" if best["total_ns"] < base["total_ns"] else "False"))
    else:
        lines.append("dse: improved=False (baseline is iso-area best)")
    return "\n".join(lines)


def sweep_networks(base: DSEConfig,
                   networks: Iterable[str] = ("resnet18", "vgg16",
                                              "bert_encoder"),
                   modes: Iterable[str] = ("original", "overlap",
                                           "transform"),
                   ) -> Dict[Tuple[str, str], DSEResult]:
    """One sweep per (network, mode), sharing journal naming through the
    per-sweep ``journal_path`` template (``{network}``/``{mode}`` are
    substituted when present)."""
    out: Dict[Tuple[str, str], DSEResult] = {}
    for net in networks:
        for mode in modes:
            path = base.journal_path
            if path:
                path = path.format(network=net, mode=mode)
            cfg = dataclasses.replace(base, network=net, mode=mode,
                                      journal_path=path)
            out[(net, mode)] = run_dse(cfg)
    return out


def best_arch_table(results: Dict[Tuple[str, str], DSEResult]) -> str:
    """Per-(network, mode) winner: lowest latency at iso-area vs the
    family default, with the frontier size alongside."""
    rows = []
    for (net, mode), res in sorted(results.items()):
        best = res.best_within_area() or res.baseline
        base = res.baseline
        rows.append((
            net, mode, best["arch_name"],
            f"{best['total_ns'] / 1e6:.3f}",
            f"{base['total_ns'] / 1e6:.3f}",
            f"{base['total_ns'] / best['total_ns']:.2f}x",
            str(len(res.frontier)),
        ))
    return _table(("network", "mode", "best_arch", "best_ms",
                   "baseline_ms", "speedup", "frontier"), rows)
