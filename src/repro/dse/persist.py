"""Content-keyed, resumable run journal for DSE sweeps.

Every evaluated design point is one JSON record::

    {"key": <sha1>, "point": {...}, "family": ..., "total_ns": ...}

``key`` is a SHA-1 over the *content* of the evaluation — network, mode,
strategy, search budget parameters, seed and the built ``ArchSpec``'s
``to_key()`` — mirroring the engine's content-keyed caches: any run that
would produce bit-identical results shares the key, regardless of which
process (or which explorer, or which machine) produced it. Re-running a
sweep therefore serves already-scored points from the journal and
performs zero new mapping searches.

Storage is pluggable (``JournalBackend``):

* ``FileBackend`` — the classic single local JSONL file. Appends flush
  eagerly so concurrent readers and killed runs observe a prefix of
  complete lines; loading tolerates a truncated final line, and later
  lines win on key collisions, so re-appends are harmless.
* ``SharedDirBackend`` — an object-store emulation over a shared
  directory (NFS mount, fuse-mounted bucket, ...): each writer appends
  to a private staging file and *publishes* whole shards by atomic
  rename into ``<root>/shards/``. Readers list the directory and merge
  all published shards later-wins by content key, so a reader never
  observes a partially-written shard and N machines can feed one sweep.
  This is the substrate of the distributed sweep subsystem
  (``repro.dse.distrib``, DESIGN.md Section 10).

Both backends support ``compact()``: rewrite the store keeping exactly
one line per content key (later-wins) and dropping any truncated tail,
so long-lived shared journals don't grow unboundedly.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
import uuid
from typing import Dict, Iterator, List, Optional, Tuple

from .. import obs


def content_key(network: str, mode: str, strategy: str, seed: int,
                n_candidates: int, max_steps: int, refine_passes: int,
                arch_key: str, objective: str = "latency") -> str:
    """Stable identity of one (network, search config, arch) evaluation.

    ``objective`` enters the blob only when it deviates from "latency"
    (the implicit objective of every pre-energy journal), so those
    journals keep serving latency sweeps for modes whose records are
    unchanged — while every other objective gets distinct keys.

    Transform-mode keys additionally carry ``energy_rev=1``: the
    energy-aware search changed what a transform evaluation *records*
    (``energy_pj`` now includes relocation energy, plus the
    ``move_energy_pj``/``edp_ns_pj``/``objective_value`` columns), and a
    resumed sweep must never mix pre-energy records with fresh ones on
    the same frontier. Original/overlap evaluations never relocate, so
    their records — and keys — are untouched."""
    blob_dict = {"network": network, "mode": mode, "strategy": strategy,
                 "seed": seed, "n_candidates": n_candidates,
                 "max_steps": max_steps, "refine_passes": refine_passes,
                 "arch_key": arch_key}
    if objective != "latency":
        blob_dict["objective"] = objective
    if mode == "transform":
        blob_dict["energy_rev"] = 1
    blob = json.dumps(blob_dict, sort_keys=True, separators=(",", ":"))
    return hashlib.sha1(blob.encode()).hexdigest()


def _parse_lines(fh) -> Iterator[Dict]:
    """Complete, keyed records of one JSONL stream (truncated tail and
    junk lines are skipped — the killed-mid-append contract)."""
    for line in fh:
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue  # truncated tail of a killed run
        if isinstance(rec, dict) and "key" in rec:
            yield rec


class JournalBackend:
    """Storage protocol behind ``RunJournal``.

    ``load`` returns the merged later-wins view; ``append`` stages one
    record; ``publish`` makes staged records visible to *other* readers
    (a no-op for backends whose appends are immediately visible);
    ``compact`` rewrites the store to one line per key and returns
    ``(lines_before, lines_after)``."""

    def load(self) -> Dict[str, Dict]:
        """Full merged later-wins view, ``{content key: record}``."""
        raise NotImplementedError

    def append(self, rec: Dict) -> None:
        """Stage one record for this writer."""
        raise NotImplementedError

    def publish(self) -> None:
        """Make staged records visible to other readers (no-op where
        appends already are)."""
        pass

    def load_new(self) -> Dict[str, Dict]:
        """Records that appeared since the last ``load``/``load_new``.
        Backends without a cheaper answer may return the full view —
        ``RunJournal.refresh`` only merges, never drops."""
        return self.load()

    def compact(self) -> Tuple[int, int]:
        """Rewrite the store to one line per key; returns
        ``(lines_before, lines_after)``."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support compaction")


class FileBackend(JournalBackend):
    """Single local JSONL file; appends are eagerly flushed."""

    def __init__(self, path: str):
        self.path = path
        self._needs_newline = False
        if os.path.exists(path):
            with open(path, "rb") as bf:
                bf.seek(0, os.SEEK_END)
                if bf.tell() > 0:
                    bf.seek(-1, os.SEEK_END)
                    # a truncated tail must not swallow the next append
                    self._needs_newline = bf.read(1) != b"\n"

    def load(self) -> Dict[str, Dict]:
        """Parse the file later-wins (truncated tail tolerated)."""
        out: Dict[str, Dict] = {}
        if os.path.exists(self.path):
            with open(self.path, "r", encoding="utf-8") as fh:
                for rec in _parse_lines(fh):
                    out[rec["key"]] = rec
        return out

    def append(self, rec: Dict) -> None:
        """Append one JSON line, eagerly flushed (concurrent readers
        and killed runs observe a prefix of complete lines)."""
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "a", encoding="utf-8") as fh:
            if self._needs_newline:
                fh.write("\n")
                self._needs_newline = False
            fh.write(json.dumps(rec, sort_keys=True) + "\n")
            fh.flush()

    def compact(self) -> Tuple[int, int]:
        """Atomically rewrite the file with one line per key."""
        if not os.path.exists(self.path):
            return (0, 0)
        with open(self.path, "r", encoding="utf-8") as fh:
            n_before = sum(1 for line in fh if line.strip())
        merged = self.load()
        tmp = self.path + ".compact.tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            for rec in merged.values():  # original append order
                fh.write(json.dumps(rec, sort_keys=True) + "\n")
        os.replace(tmp, self.path)
        self._needs_newline = False
        return (n_before, len(merged))


class SharedDirBackend(JournalBackend):
    """Object-store-style shared directory of immutable record shards.

    Writers never touch a shared file in place: ``append`` stages records
    in a private ``.staging/<writer>.jsonl``, and ``publish`` moves the
    staged batch into ``shards/`` under a fresh name with ``os.replace``
    (atomic on POSIX), so readers only ever see complete shards. The
    merged view is later-wins by content key over shards in sorted-name
    order — and since keys are *content* keys of deterministic
    evaluations, colliding records are identical and the merge order is
    immaterial; later-wins is pure deduplication. A writer crash loses at
    most its unpublished staging file, which the distributed lease
    protocol re-steals (``repro.dse.distrib.lease``)."""

    def __init__(self, root: str, writer_id: Optional[str] = None):
        self.root = root
        self.writer_id = writer_id or f"w{os.getpid()}-{uuid.uuid4().hex[:8]}"
        self._n_published = 0
        self._staged = 0
        # shards are immutable once published, so a reader only ever
        # needs to read each shard once — load_new() keeps refresh O(new
        # shards), not O(all shards), which matters in worker poll loops
        self._seen_shards: set = set()
        os.makedirs(self.shard_dir, exist_ok=True)
        os.makedirs(self._staging_dir, exist_ok=True)

    @property
    def shard_dir(self) -> str:
        """Directory of the published (immutable) record shards."""
        return os.path.join(self.root, "shards")

    @property
    def _staging_dir(self) -> str:
        return os.path.join(self.root, ".staging")

    @property
    def _staging_path(self) -> str:
        return os.path.join(self._staging_dir, f"{self.writer_id}.jsonl")

    def shards(self) -> List[str]:
        """Published shard paths in sorted-name (merge) order."""
        try:
            names = sorted(os.listdir(self.shard_dir))
        except FileNotFoundError:
            return []
        return [os.path.join(self.shard_dir, n) for n in names
                if n.endswith(".jsonl")]

    def load(self) -> Dict[str, Dict]:
        """Full merge of every published shard (resets the incremental
        ``load_new`` cursor)."""
        self._seen_shards = set()
        return self.load_new()

    def load_new(self) -> Dict[str, Dict]:
        """Merge only shards published since the previous read."""
        out: Dict[str, Dict] = {}
        for path in self.shards():
            if path in self._seen_shards:
                continue
            try:
                with open(path, "r", encoding="utf-8") as fh:
                    for rec in _parse_lines(fh):
                        out[rec["key"]] = rec
            except FileNotFoundError:
                continue  # compacted away under us; its keys are merged
            self._seen_shards.add(path)
        return out

    def append(self, rec: Dict) -> None:
        """Stage one record privately; ``publish`` makes it visible."""
        with open(self._staging_path, "a", encoding="utf-8") as fh:
            fh.write(json.dumps(rec, sort_keys=True) + "\n")
            fh.flush()
        self._staged += 1

    def publish(self) -> None:
        """Atomic-rename the staged batch into the shared shard dir."""
        if self._staged == 0:
            return
        name = f"shard-{self.writer_id}-{self._n_published:06d}.jsonl"
        os.replace(self._staging_path, os.path.join(self.shard_dir, name))
        self._n_published += 1
        self._staged = 0

    def compact(self) -> Tuple[int, int]:
        """Merge every published shard into one, then drop the originals.

        Publish-before-delete ordering keeps the merged view a superset
        of the old one at every instant, so concurrent readers are safe;
        concurrent *writers* keep publishing fresh shards untouched."""
        old = self.shards()
        n_before = 0
        merged: Dict[str, Dict] = {}
        for path in old:
            try:
                with open(path, "r", encoding="utf-8") as fh:
                    for rec in _parse_lines(fh):
                        n_before += 1
                        merged[rec["key"]] = rec
            except FileNotFoundError:
                continue
        if not old:
            return (0, 0)
        tmp = os.path.join(self._staging_dir,
                           f"compact-{self.writer_id}.jsonl")
        with open(tmp, "w", encoding="utf-8") as fh:
            for rec in merged.values():
                fh.write(json.dumps(rec, sort_keys=True) + "\n")
        os.replace(tmp, os.path.join(
            self.shard_dir, f"shard-compact-{uuid.uuid4().hex[:8]}.jsonl"))
        for path in old:
            try:
                os.remove(path)
            except FileNotFoundError:
                pass
        return (n_before, len(merged))


class RunJournal:
    """Append-only record store keyed on ``content_key`` values.

    Construct with a ``path`` (the classic local-JSONL journal), an
    explicit ``backend``, or neither (in-memory only — tests, throwaway
    sweeps). ``refresh()`` re-merges records other writers have
    published since load; ``publish()`` exposes this writer's staged
    records to them (both no-ops where the backend needs none).

    Thread-safe: every mutating or reading method serializes on one
    internal ``RLock``, so a journal shared across service job threads
    (``repro.serve``) never interleaves ``record``/``publish``/
    ``compact`` mid-write. Records are content-keyed and deterministic,
    so lock ordering can never change *what* is stored — only that each
    store happens whole."""

    def __init__(self, path: Optional[str] = None,
                 backend: Optional[JournalBackend] = None):
        assert path is None or backend is None, \
            "pass a path or a backend, not both"
        if backend is None and path is not None:
            backend = FileBackend(path)
        self.backend = backend
        self.path = getattr(backend, "path", None)
        self._lock = threading.RLock()
        self._records: Dict[str, Dict] = backend.load() if backend else {}

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._records

    def __iter__(self) -> Iterator[Dict]:
        with self._lock:
            return iter(list(self._records.values()))

    def get(self, key: str) -> Optional[Dict]:
        """The record stored under a content key, or None."""
        with self._lock:
            return self._records.get(key)

    def record(self, key: str, rec: Dict) -> Dict:
        """Store (and stage to the backend, if any) one record."""
        rec = {"key": key, **{k: v for k, v in rec.items() if k != "key"}}
        with self._lock:
            self._records[key] = rec
            if self.backend is not None:
                self.backend.append(rec)
        obs.inc("journal.records")
        return rec

    def publish(self) -> None:
        """Make records staged by ``record`` visible to other readers."""
        if self.backend is not None:
            t0 = time.perf_counter()
            with self._lock:
                self.backend.publish()
            obs.observe("journal.publish_seconds",
                        time.perf_counter() - t0)

    def refresh(self) -> int:
        """Merge records published by other writers; returns how many
        keys were new to this view. Locally-recorded entries survive
        (content keys make any collision bit-identical anyway)."""
        if self.backend is None:
            return 0
        t0 = time.perf_counter()
        with self._lock:
            fresh = self.backend.load_new()
            n_new = 0
            for k, rec in fresh.items():
                if k not in self._records:
                    n_new += 1
                self._records[k] = rec
        obs.observe("journal.refresh_seconds", time.perf_counter() - t0)
        obs.inc("journal.refresh_new", n_new)
        return n_new

    def compact(self) -> Tuple[int, int]:
        """Rewrite the backing store dropping superseded later-wins
        duplicates and any truncated tail; returns (lines_before,
        lines_after). Staged records are published first, so the
        rebuilt in-memory view never loses a ``record`` this writer
        made but had not yet made visible (shared-dir backends stage;
        file backends publish as a no-op). In-memory journals have
        nothing to compact."""
        with self._lock:
            if self.backend is None:
                return (len(self._records), len(self._records))
            self.backend.publish()
            out = self.backend.compact()
            self._records = self.backend.load()
            return out
