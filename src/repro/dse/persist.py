"""Content-keyed, resumable JSONL run journal for DSE sweeps.

Every evaluated design point appends one JSON line::

    {"key": <sha1>, "point": {...}, "family": ..., "total_ns": ..., ...}

``key`` is a SHA-1 over the *content* of the evaluation — network, mode,
strategy, search budget parameters, seed and the built ``ArchSpec``'s
``to_key()`` — mirroring the engine's content-keyed caches: any run that
would produce bit-identical results shares the key, regardless of which
process (or which explorer) produced it. Re-running a sweep therefore
serves already-scored points from the journal and performs zero new
mapping searches.

Loading tolerates a truncated final line (a run killed mid-append); later
lines win on key collisions, so re-appends are harmless.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, Iterator, Optional


def content_key(network: str, mode: str, strategy: str, seed: int,
                n_candidates: int, max_steps: int, refine_passes: int,
                arch_key: str, objective: str = "latency") -> str:
    """Stable identity of one (network, search config, arch) evaluation.

    ``objective`` enters the blob only when it deviates from "latency"
    (the implicit objective of every pre-energy journal), so those
    journals keep serving latency sweeps for modes whose records are
    unchanged — while every other objective gets distinct keys.

    Transform-mode keys additionally carry ``energy_rev=1``: the
    energy-aware search changed what a transform evaluation *records*
    (``energy_pj`` now includes relocation energy, plus the
    ``move_energy_pj``/``edp_ns_pj``/``objective_value`` columns), and a
    resumed sweep must never mix pre-energy records with fresh ones on
    the same frontier. Original/overlap evaluations never relocate, so
    their records — and keys — are untouched."""
    blob_dict = {"network": network, "mode": mode, "strategy": strategy,
                 "seed": seed, "n_candidates": n_candidates,
                 "max_steps": max_steps, "refine_passes": refine_passes,
                 "arch_key": arch_key}
    if objective != "latency":
        blob_dict["objective"] = objective
    if mode == "transform":
        blob_dict["energy_rev"] = 1
    blob = json.dumps(blob_dict, sort_keys=True, separators=(",", ":"))
    return hashlib.sha1(blob.encode()).hexdigest()


class RunJournal:
    """Append-only JSONL store keyed on ``content_key`` values.

    ``path=None`` keeps the journal in memory only (tests, throwaway
    sweeps). Appends flush eagerly so concurrent readers and killed runs
    observe a prefix of complete lines."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._records: Dict[str, Dict] = {}
        self._needs_newline = False
        if path and os.path.exists(path):
            with open(path, "rb") as bf:
                bf.seek(0, os.SEEK_END)
                if bf.tell() > 0:
                    bf.seek(-1, os.SEEK_END)
                    # a truncated tail must not swallow the next append
                    self._needs_newline = bf.read(1) != b"\n"
            with open(path, "r", encoding="utf-8") as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        continue  # truncated tail of a killed run
                    if isinstance(rec, dict) and "key" in rec:
                        self._records[rec["key"]] = rec

    def __len__(self) -> int:
        return len(self._records)

    def __contains__(self, key: str) -> bool:
        return key in self._records

    def __iter__(self) -> Iterator[Dict]:
        return iter(self._records.values())

    def get(self, key: str) -> Optional[Dict]:
        return self._records.get(key)

    def record(self, key: str, rec: Dict) -> Dict:
        """Store (and append, if file-backed) one evaluation record."""
        rec = {"key": key, **{k: v for k, v in rec.items() if k != "key"}}
        self._records[key] = rec
        if self.path:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(self.path, "a", encoding="utf-8") as fh:
                if self._needs_newline:
                    fh.write("\n")
                    self._needs_newline = False
                fh.write(json.dumps(rec, sort_keys=True) + "\n")
                fh.flush()
        return rec
