"""Declarative architecture parameter spaces for design-space exploration.

A ``ParamSpace`` is a named family (one of the ``ArchSpec`` factories in
``core.arch``: ``dram_pim``, ``reram_pim``, ``tpu_spatial``) plus ordered
value axes per parameter and validity constraints over joint assignments.
Points are immutable ``DesignPoint``s (canonical sorted param tuples) with
stable content keys, so journals, Pareto payloads and explorer dedup sets
all agree on identity.

Two axes go beyond the factory signatures and are applied on top of the
built spec: ``timing_scale`` multiplies every HBM timing parameter *and*
the pinned per-op PIM latencies (a faster/slower speed bin — energies are
untouched, so the power proxy rises as timing shrinks), and
``target_level`` moves the overlap-analysis level (paper Section IV-H).
A ``word_bits`` axis additionally rescales pinned (16-bit-measured) op
latencies with precision — add ~n, mul ~n^2, the Section IV-C bit-serial
structure — so low precision buys energy *and* speed at the model's
honest exchange rate instead of dominating for free.

Cost proxies (``core.perf_model.arch_area_proxy`` / ``arch_power_proxy``)
are exposed through ``ParamSpace.costs`` so explorers and reports share one
definition of the area/power objectives.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from ..core.arch import ARCH_PRESETS, ArchSpec
from ..core.perf_model import arch_area_proxy, arch_power_proxy

Params = Dict[str, object]


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One assignment of the space's parameters (canonical, hashable)."""

    family: str
    params: Tuple[Tuple[str, object], ...]  # sorted by name

    @staticmethod
    def make(family: str, params: Params) -> "DesignPoint":
        """Canonicalize a params dict into a ``DesignPoint``."""
        return DesignPoint(family, tuple(sorted(params.items())))

    def as_dict(self) -> Params:
        """The point's parameters as a plain dict."""
        return dict(self.params)

    def key(self) -> str:
        """Stable content key (process-independent)."""
        body = ",".join(f"{k}={v}" for k, v in self.params)
        return f"{self.family}({body})"

    def __str__(self) -> str:
        return self.key()


def _scale_precision(arch: ArchSpec, word_bits: int) -> ArchSpec:
    """Rescale *pinned* PIM op latencies for a non-16-bit precision.

    The factories pin measured 16-bit latencies (Fig 6/7); the derived
    AAP model (Section IV-C) says a full add is ``4n+1`` AAPs (~linear in
    n) and a mul is n sequential adds (~quadratic). Without this, low
    precision would get its ~2x energy win at unchanged latency and
    dominate the frontier as a pure modeling artifact."""
    if word_bits == 16:
        return arch
    r = word_bits / 16.0
    scale = {"add": r, "mul": r * r}
    levels = tuple(
        dataclasses.replace(
            lv, pim_ops=None if lv.pim_ops is None
            else {op: ns * scale.get(op, r) for op, ns in
                  lv.pim_ops.items()})
        for lv in arch.levels)
    return dataclasses.replace(arch, levels=levels)


def _scale_timing(arch: ArchSpec, scale: float) -> ArchSpec:
    """Scale every timing parameter and pinned PIM op latency by ``scale``
    (a DRAM speed bin). Energies stay — power = energy/time moves."""
    if scale == 1.0:
        return arch
    t = arch.timing
    timing = dataclasses.replace(
        t, t_rc=t.t_rc * scale, t_rcd=t.t_rcd * scale,
        t_ras=t.t_ras * scale, t_cl=t.t_cl * scale, t_rrd=t.t_rrd * scale,
        t_wr=t.t_wr * scale, t_ccd_s=t.t_ccd_s * scale,
        t_ccd_l=t.t_ccd_l * scale)
    levels = tuple(
        dataclasses.replace(
            lv, pim_ops=None if lv.pim_ops is None
            else {op: ns * scale for op, ns in lv.pim_ops.items()})
        for lv in arch.levels)
    return dataclasses.replace(arch, timing=timing, levels=levels,
                               name=f"{arch.name}_ts{scale:g}")


@dataclasses.dataclass
class ParamSpace:
    """Ordered value axes + validity constraints over one arch family.

    ``axes`` order is the grid-enumeration order (first axis outermost);
    per-axis value order defines mutation neighborhoods (a mutation steps
    to an adjacent value). ``factory_params`` names the axes forwarded to
    the ``ARCH_PRESETS`` factory; the rest are post-build modifiers
    (``timing_scale``, ``target_level``)."""

    family: str
    axes: Dict[str, Tuple]
    constraints: List[Callable[[Params], bool]] = \
        dataclasses.field(default_factory=list)
    defaults: Params = dataclasses.field(default_factory=dict)
    factory_params: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.family not in ARCH_PRESETS:
            raise KeyError(f"unknown arch family {self.family!r}")
        if not self.factory_params:
            self.factory_params = tuple(
                n for n in self.axes if n not in ("timing_scale",
                                                  "target_level"))

    # -- membership ----------------------------------------------------------

    def is_valid(self, params: Params) -> bool:
        """Full assignment, on-axis values, all constraints satisfied."""
        for name, value in params.items():
            if name not in self.axes or value not in self.axes[name]:
                return False
        if set(params) != set(self.axes):
            return False
        return all(c(params) for c in self.constraints)

    def point(self, **params) -> DesignPoint:
        """A validated point: the given params over the defaults
        (raises ``ValueError`` for off-axis or constraint-violating
        assignments)."""
        full = {**self.defaults, **params}
        if not self.is_valid(full):
            raise ValueError(f"invalid point for {self.family}: {full}")
        return DesignPoint.make(self.family, full)

    def default(self) -> DesignPoint:
        """The space's baseline point (the factory-default config)."""
        return self.point()

    @property
    def size(self) -> int:
        """Grid size before constraint filtering."""
        n = 1
        for vals in self.axes.values():
            n *= len(vals)
        return n

    # -- generation ----------------------------------------------------------

    def enumerate(self) -> Iterator[DesignPoint]:
        """All valid points in grid order (first axis outermost)."""
        names = list(self.axes)
        for combo in itertools.product(*(self.axes[n] for n in names)):
            params = dict(zip(names, combo))
            if all(c(params) for c in self.constraints):
                yield DesignPoint.make(self.family, params)

    def sample(self, rng: random.Random, max_tries: int = 256) \
            -> DesignPoint:
        """One uniform-ish valid point (rejection sampling)."""
        for _ in range(max_tries):
            params = {n: rng.choice(vals) for n, vals in self.axes.items()}
            if all(c(params) for c in self.constraints):
                return DesignPoint.make(self.family, params)
        return self.default()

    # -- genetic operators (evolutionary explorer) ---------------------------

    def mutate(self, point: DesignPoint, rng: random.Random,
               max_tries: int = 64) -> DesignPoint:
        """Step one random gene to an adjacent value on its axis (falls
        back to a fresh sample if no valid neighbor is found)."""
        base = point.as_dict()
        for _ in range(max_tries):
            params = dict(base)
            name = rng.choice(list(self.axes))
            vals = self.axes[name]
            if len(vals) == 1:
                continue
            i = vals.index(params[name])
            j = i + rng.choice((-1, 1))
            if not 0 <= j < len(vals):
                j = i - (j - i)
            params[name] = vals[j]
            if params != base and all(c(params) for c in self.constraints):
                return DesignPoint.make(self.family, params)
        return self.sample(rng)

    def crossover(self, a: DesignPoint, b: DesignPoint,
                  rng: random.Random, max_tries: int = 64) -> DesignPoint:
        """Uniform per-gene crossover (falls back to mutation of ``a``)."""
        pa, pb = a.as_dict(), b.as_dict()
        for _ in range(max_tries):
            params = {n: (pa if rng.random() < 0.5 else pb)[n]
                      for n in self.axes}
            if all(c(params) for c in self.constraints):
                return DesignPoint.make(self.family, params)
        return self.mutate(a, rng)

    # -- realization ---------------------------------------------------------

    def build(self, point: DesignPoint) -> ArchSpec:
        """Materialize the ``ArchSpec`` for a point."""
        params = point.as_dict()
        factory = ARCH_PRESETS[self.family]
        arch = factory(**{n: params[n] for n in self.factory_params})
        target = params.get("target_level")
        if target is not None and target != arch.target_level:
            arch = dataclasses.replace(arch, target_level=target)
        if "word_bits" in params:
            arch = _scale_precision(arch, params["word_bits"])
        arch = _scale_timing(arch, params.get("timing_scale", 1.0))
        return arch

    def costs(self, point: DesignPoint) -> Dict[str, float]:
        """Static (mapping-independent) cost proxies of a point."""
        arch = self.build(point)
        return {"area_mm2": arch_area_proxy(arch),
                "power_w": arch_power_proxy(arch)}


# ---------------------------------------------------------------------------
# The shipped spaces, one per ArchSpec factory.
# ---------------------------------------------------------------------------

def dram_space() -> ParamSpace:
    """HBM2 DRAM PIM: channel/bank/column allocation, precision, speed
    bin, analysis level. The default point *is* ``dram_pim()``."""
    return ParamSpace(
        family="dram_pim",
        axes={
            "channels_per_layer": (1, 2, 4, 8),
            "banks_per_channel": (2, 4, 8, 16, 32),
            "columns_per_bank": (2048, 4096, 8192, 16384),
            "word_bits": (8, 16),
            "timing_scale": (1.0, 1.25),
            "target_level": ("Bank", "Channel"),
        },
        constraints=[
            # keep the analysis grids (and per-point search cost) bounded
            lambda p: (p["channels_per_layer"] * p["banks_per_channel"]
                       <= 64),
            lambda p: (p["channels_per_layer"] * p["banks_per_channel"]
                       * p["columns_per_bank"] <= 1 << 21),
        ],
        defaults={"channels_per_layer": 2, "banks_per_channel": 8,
                  "columns_per_bank": 8192, "word_bits": 16,
                  "timing_scale": 1.0, "target_level": "Bank"},
    )


def reram_space() -> ParamSpace:
    """FloatPIM-style ReRAM: tile/block/column allocation + precision."""
    return ParamSpace(
        family="reram_pim",
        axes={
            "tiles_per_layer": (1, 2, 4),
            "blocks_per_tile": (8, 16, 32, 64),
            "columns_per_block": (256, 512, 1024),
            "word_bits": (8, 16),
            "timing_scale": (1.0, 1.25),
        },
        constraints=[
            lambda p: p["tiles_per_layer"] * p["blocks_per_tile"] <= 128,
        ],
        defaults={"tiles_per_layer": 2, "blocks_per_tile": 64,
                  "columns_per_block": 1024, "word_bits": 16,
                  "timing_scale": 1.0},
    )


def tpu_space() -> ParamSpace:
    """TPU-like spatial config (adaptation level 3): cores and MXU lanes."""
    return ParamSpace(
        family="tpu_spatial",
        axes={
            "cores": (2, 4, 8, 16),
            "lanes": (64 * 64, 128 * 128),
        },
        defaults={"cores": 8, "lanes": 128 * 128},
    )


SPACES: Dict[str, Callable[[], ParamSpace]] = {
    "dram_pim": dram_space,
    "reram_pim": reram_space,
    "tpu_spatial": tpu_space,
}


def get_space(family: str) -> ParamSpace:
    """The shipped default space of an arch family (``SPACES``)."""
    try:
        return SPACES[family]()
    except KeyError:
        raise KeyError(
            f"unknown space {family!r}; one of {sorted(SPACES)}") from None
