"""Fault-tolerant training loop.

Features (DESIGN.md Section 7): jitted sharded train step, gradient
accumulation, checkpoint/auto-resume (atomic, newest-valid), elastic
re-mesh on restore, per-step straggler deadline with skip-and-log, and
a failure-injection hook used by the tests.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import DataConfig, SyntheticStream
from repro.launch import steps as steps_lib
from repro.launch.sharding import (batch_specs, opt_specs, param_specs,
                                   to_shardings)
from repro.models import model_zoo
from repro.models.common import ModelConfig
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import OptimizerConfig, init_opt_state

log = logging.getLogger("repro.trainer")
PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    step_deadline_s: Optional[float] = None   # straggler mitigation
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh,
                 opt_cfg: Optional[OptimizerConfig] = None,
                 tcfg: Optional[TrainerConfig] = None,
                 dcfg: Optional[DataConfig] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.opt_cfg = opt_cfg or OptimizerConfig()
        self.tcfg = tcfg or TrainerConfig()
        self.dcfg = dcfg or DataConfig()
        self.stream = SyntheticStream(cfg, self.dcfg)
        self.step = 0
        self.metrics_history: list = []
        self._build()

    # -- setup ---------------------------------------------------------------

    def _build(self):
        cfg, mesh = self.cfg, self.mesh
        pshapes = model_zoo.param_shapes(cfg)
        self.pspecs = param_specs(pshapes, mesh)
        self.pshard = to_shardings(self.pspecs, mesh)
        oshapes = jax.eval_shape(init_opt_state, pshapes)
        ospecs = {"mu": opt_specs(self.pspecs, pshapes, mesh),
                  "nu": opt_specs(self.pspecs, pshapes, mesh),
                  "step": jax.sharding.PartitionSpec()}
        self.oshard = to_shardings(ospecs, mesh)
        bspecs = batch_specs(cfg, self.dcfg.batch, mesh, "train")
        self.bshard = to_shardings(bspecs, mesh)

        step_fn = steps_lib.make_train_step(cfg, self.opt_cfg)
        self.train_step = jax.jit(
            step_fn,
            in_shardings=(self.pshard, self.oshard, self.bshard),
            out_shardings=(self.pshard, self.oshard, None),
            donate_argnums=(0, 1))

    def init_state(self):
        with self.mesh:
            params = jax.jit(
                lambda k: model_zoo.init_params(self.cfg, k),
                out_shardings=self.pshard)(
                    jax.random.PRNGKey(self.tcfg.seed))
            opt_state = jax.jit(init_opt_state,
                                out_shardings=self.oshard)(params)
        return params, opt_state

    # -- checkpointing / elastic restore -------------------------------------

    def maybe_restore(self):
        if not self.tcfg.ckpt_dir:
            return None
        pshapes = model_zoo.param_shapes(self.cfg)
        oshapes = jax.eval_shape(init_opt_state, pshapes)
        res = ckpt_lib.restore(
            self.tcfg.ckpt_dir,
            {"params": pshapes, "opt": oshapes},
            {"params": self.pshard, "opt": self.oshard})
        if res is None:
            return None
        step, trees, meta = res
        self.step = step
        log.info("restored step %d (saved on mesh %s, restored on %s)",
                 step, meta.get("mesh"), tuple(self.mesh.shape.values()))
        return trees["params"], trees["opt"]

    def save(self, params, opt_state):
        if not self.tcfg.ckpt_dir:
            return
        ckpt_lib.save(self.tcfg.ckpt_dir, self.step,
                      {"params": params, "opt": opt_state},
                      meta={"mesh": list(self.mesh.shape.values()),
                            "arch": self.cfg.arch_id})
        ckpt_lib.prune(self.tcfg.ckpt_dir, self.tcfg.keep_ckpts)

    # -- loop -----------------------------------------------------------------

    def _device_batch(self, batch_np: Dict[str, np.ndarray]):
        return {k: jax.device_put(v, self.bshard[k])
                for k, v in batch_np.items()}

    def run(self, fail_at: Optional[int] = None) -> Dict[str, float]:
        """Train; ``fail_at`` raises a simulated failure at that step
        (tests restart the trainer and verify resume)."""
        restored = self.maybe_restore()
        if restored is not None:
            params, opt_state = restored
        else:
            params, opt_state = self.init_state()

        last = None
        while self.step < self.tcfg.steps:
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"injected failure at step {self.step}")
            t0 = time.time()
            batch = self._device_batch(self.stream.batch_at(self.step))
            with self.mesh:
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch)
            if self.tcfg.step_deadline_s is not None:
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                if dt > self.tcfg.step_deadline_s:
                    log.warning("straggler: step %d took %.2fs "
                                "(deadline %.2fs)", self.step, dt,
                                self.tcfg.step_deadline_s)
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or \
                    self.step == self.tcfg.steps:
                last = {k: float(v) for k, v in metrics.items()}
                self.metrics_history.append({"step": self.step, **last})
                log.info("step %d: %s", self.step, last)
            if self.tcfg.ckpt_dir and \
                    self.step % self.tcfg.ckpt_every == 0:
                self.save(params, opt_state)
        if self.tcfg.ckpt_dir:
            self.save(params, opt_state)
        self._final = (params, opt_state)
        return last or {}
