"""AdamW + cosine schedule + global-norm clipping, hand-rolled in JAX
(no optax in this environment). Optimizer state lives in fp32; supports
gradient accumulation and an optional top-k gradient-compression hook for
cross-pod all-reduce (DESIGN.md Section 7).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: PyTree) -> Dict:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: OptimizerConfig, params: PyTree, grads: PyTree,
                 state: Dict) -> Tuple[PyTree, Dict, Dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
        state["nu"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": mu, "nu": nu, "step": step}, metrics


# ---------------------------------------------------------------------------
# Gradient compression hook (top-k magnitude sparsification with error
# feedback) — applied per-leaf BEFORE the cross-pod reduction when enabled.
# ---------------------------------------------------------------------------

def topk_compress(g, frac: float = 0.1):
    """Keep the top ``frac`` magnitudes of a gradient leaf; returns the
    sparsified dense tensor (residual is the caller's error-feedback)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(g.shape)
