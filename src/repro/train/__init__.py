"""train subpackage."""
