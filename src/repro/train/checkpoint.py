"""Fault-tolerant checkpointing (no orbax in this container).

Format: one compressed msgpack file per save (zstd when ``zstandard`` is
installed, stdlib zlib otherwise — the magic records which) containing the
flattened
param/opt trees (host-gathered, logical global arrays) + metadata (step,
mesh shape, config id). Writes are atomic (tmp + rename); restore scans
for the newest *valid* checkpoint, skipping corrupted/partial files —
together with the stateless-seeded data pipeline this gives
checkpoint/restart with elastic re-meshing (restore re-shards onto
whatever mesh the relaunch built).
"""
from __future__ import annotations

import dataclasses
import os
import re
import struct
from typing import Any, Dict, Optional, Tuple

import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # zstd when available; stdlib zlib otherwise (ISSUE 1: no hard dep)
    import zstandard
except ImportError:
    zstandard = None

PyTree = Any

_MAGIC = b"RPCK1"      # zstd-compressed payload
_MAGIC_ZLIB = b"RPCK2"  # zlib-compressed payload (fallback codec)


class MissingCodecError(RuntimeError):
    """A checkpoint needs a codec this environment lacks. Distinct from
    corruption: restore() must NOT silently skip such files (that would
    roll training back to an older checkpoint)."""


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _pack_array(a: np.ndarray) -> Dict:
    if a.dtype == jnp.bfloat16:  # numpy serializes ml_dtypes as void
        return {"dtype": "bfloat16", "shape": list(a.shape),
                "data": a.view(np.uint16).tobytes()}
    return {"dtype": a.dtype.str, "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: Dict) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        return np.frombuffer(d["data"], dtype=np.uint16).reshape(
            d["shape"]).view(jnp.bfloat16)
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(
        d["shape"])


def save(ckpt_dir: str, step: int, trees: Dict[str, PyTree],
         meta: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    payload = {
        "meta": {**(meta or {}), "step": int(step)},
        "trees": {name: {k: _pack_array(v)
                         for k, v in _flatten(tree).items()}
                  for name, tree in trees.items()},
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    if zstandard is not None:
        magic, comp = _MAGIC, zstandard.ZstdCompressor(level=3).compress(raw)
    else:
        magic, comp = _MAGIC_ZLIB, zlib.compress(raw, 6)
    blob = magic + struct.pack("<Q", len(comp)) + comp
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.rpck")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)  # atomic publish
    return path


def _load_file(path: str) -> Dict:
    with open(path, "rb") as f:
        blob = f.read()
    if blob.startswith(_MAGIC):
        codec = "zstd"
    elif blob.startswith(_MAGIC_ZLIB):
        codec = "zlib"
    else:
        raise ValueError("bad magic")
    (n,) = struct.unpack("<Q", blob[5:13])
    comp = blob[13:13 + n]
    if len(comp) != n:
        raise ValueError("truncated checkpoint")
    if codec == "zstd":
        if zstandard is None:
            raise MissingCodecError(
                "checkpoint was written with zstd but zstandard is not "
                "installed in this environment")
        raw = zstandard.ZstdDecompressor().decompress(comp)
    else:
        raw = zlib.decompress(comp)
    return msgpack.unpackb(raw, raw=False)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for fn in os.listdir(ckpt_dir):
        m = re.fullmatch(r"ckpt_(\d+)\.rpck", fn)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, templates: Dict[str, PyTree],
            shardings: Optional[Dict[str, PyTree]] = None
            ) -> Optional[Tuple[int, Dict[str, PyTree], Dict]]:
    """Restore the newest VALID checkpoint, re-sharding each leaf with the
    provided shardings (elastic re-mesh). Corrupted files are skipped."""
    if not os.path.isdir(ckpt_dir):
        return None
    files = sorted(
        (fn for fn in os.listdir(ckpt_dir)
         if re.fullmatch(r"ckpt_\d+\.rpck", fn)), reverse=True)
    for fn in files:
        try:
            payload = _load_file(os.path.join(ckpt_dir, fn))
        except MissingCodecError:
            raise  # not corruption — skipping would lose training progress
        except Exception:
            continue  # partial/corrupt — fall back to an older one
        out = {}
        ok = True
        for name, template in templates.items():
            if name not in payload["trees"]:
                ok = False
                break
            flat = payload["trees"][name]
            leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
            new_leaves = []
            for path, leaf in leaves:
                key = "/".join(_path_str(p) for p in path)
                if key not in flat:
                    ok = False
                    break
                arr = _unpack_array(flat[key])
                if tuple(arr.shape) != tuple(leaf.shape):
                    ok = False
                    break
                sh = None
                if shardings and name in shardings:
                    sh = _lookup_path(shardings[name], path)
                if sh is not None:
                    new_leaves.append(jax.device_put(arr, sh))
                else:
                    new_leaves.append(jnp.asarray(arr))
            if not ok:
                break
            out[name] = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template), new_leaves)
        if ok:
            return payload["meta"]["step"], out, payload["meta"]
    return None


def _lookup_path(tree, path):
    node = tree
    try:
        for p in path:
            if hasattr(p, "key"):
                node = node[p.key]
            elif hasattr(p, "idx"):
                node = node[p.idx]
        return node
    except (KeyError, IndexError, TypeError):
        return None


def prune(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    files = sorted(
        (fn for fn in os.listdir(ckpt_dir)
         if re.fullmatch(r"ckpt_\d+\.rpck", fn)))
    for fn in files[:-keep]:
        os.remove(os.path.join(ckpt_dir, fn))
