"""Loop-aware cost analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — with
``lax.scan`` over layers/microbatches/KV-chunks that undercounts FLOPs,
bytes and collective traffic by orders of magnitude. This analyzer walks
the compiled module text, computes per-computation costs bottom-up with a
per-computation symbol table (instruction -> result shapes), and
multiplies ``while`` bodies by their trip counts (XLA annotates counted
loops with ``backend_config={"known_trip_count":{"n":...}}``; the loop
condition's constant is the fallback).

Costs per instruction:
  * dot: 2 * numel(result) * contracted_size (lhs_contracting_dims against
    the lhs operand's recorded shape);
  * convolution: 2 * numel(result) * numel(rhs) / out_features;
  * collectives: result bytes, accumulated separately by kind;
  * memory-traffic proxy: result bytes of materializing ops + operand
    bytes of dot/conv/copy/gather/scatter/dynamic-slice/collective ops.

Cross-checked against XLA cost_analysis on loop-free modules in
tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")
# HBM-traffic model (TPU semantics): count dot/conv operands+results
# (weights + activations at matmul boundaries — the dominant real
# traffic), collective payloads, KV-cache updates (DUS), gathers
# (embedding lookups) and reduce results. Fusion results, loop-carry
# copies and dynamic-slices are EXCLUDED: on TPU they are either fused
# on-chip or in-place buffer aliases; the CPU backend materializes them
# and would inflate the memory term ~5x (measured on olmo_1b train_4k).
_MATERIAL = ("reduce", "sort", "custom-call")
_READ_OPERANDS = ()

Shapes = List[Tuple[str, List[int]]]


def _shapes_in(text: str) -> Shapes:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes_of(shapes: Shapes) -> int:
    return sum(_numel(d) * _DTYPE_BYTES.get(t, 4) for t, d in shapes)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k in _COLLECTIVES:
            self.coll_counts[k] += other.coll_counts[k] * mult
            self.coll_bytes_by_kind[k] += \
                other.coll_bytes_by_kind[k] * mult


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_shapes: Shapes
    operands: List[str]
    rest: str


_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_instr(line: str) -> Optional[Instr]:
    m = _DEF_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # result type(s) precede the op name; op name is a bare word before '('
    om = re.match(r"((?:\([^=]*?\)|[^\s(]+))\s+([\w\-]+)\(", rest)
    if om is None:
        om = re.match(r"()([\w\-]+)\(", rest)
        if om is None:
            return None
    result_t, op = om.group(1), om.group(2)
    args = rest[om.end():]
    # operand list ends at the matching close paren: take up to the first
    # '),' or trailing ')'
    depth = 1
    end = 0
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operand_text = args[:end]
    operands = _OPERAND_RE.findall(operand_text)
    return Instr(name=name, op=op, result_shapes=_shapes_in(result_t),
                 operands=operands, rest=rest)


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def split_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    body: List[Instr] = []
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped \
                    and "=" not in stripped.split("->")[0]:
                m = _HDR_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    body = []
        else:
            if stripped == "}" or stripped.startswith("} "):
                comps[cur] = body
                cur = None
            else:
                ins = _parse_instr(line)
                if ins:
                    body.append(ins)
    return comps


def _trip_count(instr: Instr, comps: Dict[str, List[Instr]]) -> int:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', instr.rest)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w.\-]+)", instr.rest)
    best = 1
    if cm and cm.group(1) in comps:
        for ins in comps[cm.group(1)]:
            k = re.search(r"constant\((\d+)\)", ins.rest)
            if k:
                best = max(best, int(k.group(1)))
    return best


def analyze(text: str) -> Cost:
    comps = split_computations(text)
    memo: Dict[str, Cost] = {}

    def comp_cost(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Cost()
        sym: Dict[str, Shapes] = {}
        total = Cost()
        for ins in comps[name]:
            sym[ins.name] = ins.result_shapes
            op = ins.op
            res_b = _bytes_of(ins.result_shapes)
            if op == "dot":
                res_n = sum(_numel(d) for _, d in ins.result_shapes)
                k = 1
                mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                               ins.rest)
                lhs = sym.get(ins.operands[0], []) if ins.operands else []
                if mm and lhs:
                    dims = lhs[0][1]
                    for di in mm.group(1).split(","):
                        if di and int(di) < len(dims):
                            k *= dims[int(di)]
                total.flops += 2.0 * res_n * max(k, 1)
                total.bytes += res_b + sum(
                    _bytes_of(sym.get(o, [])) for o in ins.operands[:2])
            elif op == "convolution":
                res_n = sum(_numel(d) for _, d in ins.result_shapes)
                rhs = sym.get(ins.operands[1], []) if \
                    len(ins.operands) > 1 else []
                if rhs:
                    rd = rhs[0][1]
                    total.flops += 2.0 * res_n * max(
                        _numel(rd) // max(rd[-1] if rd else 1, 1), 1)
                total.bytes += res_b + sum(
                    _bytes_of(sym.get(o, [])) for o in ins.operands[:2])
            elif any(op.startswith(c) for c in _COLLECTIVES):
                if op.endswith("-done"):
                    continue
                kind = next(c for c in _COLLECTIVES if op.startswith(c))
                total.coll_bytes += res_b
                total.coll_counts[kind] += 1
                total.coll_bytes_by_kind[kind] += res_b
                total.bytes += res_b
            elif op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                trips = _trip_count(ins, comps)
                if bm:
                    total.add(comp_cost(bm.group(1), stack + (name,)),
                              trips)
            elif op == "conditional":
                for bc in re.finditer(
                        r"(?:branch_computations|true_computation|"
                        r"false_computation)=\{?([^},]*)\}?", ins.rest):
                    for nm in bc.group(1).split(","):
                        nm = nm.strip().lstrip("%")
                        if nm:
                            total.add(comp_cost(nm, stack + (name,)))
            else:
                if op == "gather":
                    # embedding lookup: reads what it writes
                    total.bytes += 2 * res_b
                elif op == "dynamic-update-slice":
                    # KV-cache update: in-place write of the update only
                    upd = sym.get(ins.operands[1], []) if \
                        len(ins.operands) > 1 else []
                    total.bytes += 2 * _bytes_of(upd)
                elif op == "scatter":
                    upd = sym.get(ins.operands[-1], [])
                    total.bytes += 2 * _bytes_of(upd)
                elif op in _MATERIAL:
                    total.bytes += res_b
                # descend into called computations (fusions etc.)
                for cm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                      ins.rest):
                    total.add(comp_cost(cm.group(1), stack + (name,)))
        memo[name] = total
        return total

    entry = None
    for line in text.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", s)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        entry = max(comps, key=lambda k: len(comps[k])) if comps else ""
    return comp_cost(entry)
