"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs   / (chips * peak_FLOP/s)
    memory term     = HLO_bytes   / (chips * HBM_bw)
    collective term = coll_bytes  / (chips * link_bw)

``cost_analysis()`` supplies FLOPs and bytes accessed; collective bytes
are NOT in cost_analysis, so we parse the post-optimization HLO text and
sum operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all arrays in an HLO result type string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVES}
    nbytes = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result = TYPE collective-op(...)
        m = re.match(r"(?:ROOT\s+)?[%\w.\-]+\s*=\s*(\([^)]*\)|[^=(]+?)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", s)
        if not m:
            continue
        if "-done(" in s:
            continue  # count async pairs once (at -start)
        kind = m.group(2)
        counts[kind] += 1
        nbytes[kind] += _shape_bytes(m.group(1))
    return CollectiveStats(counts=counts, bytes_by_kind=nbytes)


@dataclasses.dataclass
class Roofline:
    flops: float                 # whole-program HLO FLOPs
    hbm_bytes: float             # whole-program bytes accessed
    collective_bytes: float      # summed collective operand bytes
    chips: int
    per_device: bool             # cost_analysis numbers are per device

    @property
    def compute_s(self) -> float:
        div = 1 if self.per_device else self.chips
        return self.flops / div / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        div = 1 if self.per_device else self.chips
        return self.hbm_bytes / div / HBM_BW

    @property
    def collective_s(self) -> float:
        # HLO text is per-partition under SPMD: bytes are per device
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
        }


def from_compiled(compiled, mesh_devices: int) -> Tuple[Roofline,
                                                        CollectiveStats]:
    """Primary terms from the loop-aware analyzer (hlo_cost); XLA's own
    cost_analysis (which counts while bodies once) is kept for reference
    in the dry-run JSON."""
    from . import hlo_cost
    cost = hlo_cost.analyze(compiled.as_text())
    colls = CollectiveStats(
        counts={k: int(v) for k, v in cost.coll_counts.items()},
        bytes_by_kind={k: int(v)
                       for k, v in cost.coll_bytes_by_kind.items()})
    rl = Roofline(flops=cost.flops, hbm_bytes=cost.bytes,
                  collective_bytes=cost.coll_bytes,
                  chips=mesh_devices, per_device=True)
    return rl, colls


def xla_cost_reference(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}


def model_flops(n_params: int, tokens: int, active_params: int = 0,
                training: bool = True) -> float:
    """MODEL_FLOPS = 6*N*D (training) or 2*N*D (inference); MoE uses
    active params."""
    n = active_params or n_params
    mult = 6 if training else 2
    return mult * n * tokens
