"""roofline subpackage."""
