"""Batched serving engine (prefill + decode with a fixed-size KV cache)."""
from .engine import Engine, ServeConfig

__all__ = ["Engine", "ServeConfig"]
