"""serve subpackage."""
