"""Serving subsystem: token generation and mapping-as-a-service.

Two engines live here. ``Engine``/``ServeConfig`` (``serve.engine``) is
the batched LM inference engine (prefill + decode with a fixed-size KV
cache). ``MappingService`` (``serve.service``) is the deployment-time
DSE service: a ``MappingRequest`` ("this network, this budget") in, the
best (arch, mapping) pair and its Pareto frontier out — backed by the
content-keyed run journal as a cross-request cache, a shared
cross-request ``OverlapEngine``, and a staged coalescing job queue
with admission control (``serve.jobs``). ``MappingHTTPServer``
(``serve.transport``) exposes the same wire forms over HTTP. See
DESIGN.md Sections 11 and 13.
"""
from .engine import Engine, ServeConfig
from .jobs import Job, JobQueue, QueueFull, QueueShutdown
from .service import MappingRequest, MappingResponse, MappingService
from .transport import MappingHTTPServer

__all__ = ["Engine", "ServeConfig", "Job", "JobQueue", "QueueFull",
           "QueueShutdown", "MappingRequest", "MappingResponse",
           "MappingService", "MappingHTTPServer"]
