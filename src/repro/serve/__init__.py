"""Serving subsystem: token generation and mapping-as-a-service.

Two engines live here. ``Engine``/``ServeConfig`` (``serve.engine``) is
the batched LM inference engine (prefill + decode with a fixed-size KV
cache). ``MappingService`` (``serve.service``) is the deployment-time
DSE service: a ``MappingRequest`` ("this network, this budget") in, the
best (arch, mapping) pair and its Pareto frontier out — backed by the
content-keyed run journal as a cross-request cache and a coalescing
job queue (``serve.jobs``). See DESIGN.md Section 11.
"""
from .engine import Engine, ServeConfig
from .jobs import Job, JobQueue
from .service import MappingRequest, MappingResponse, MappingService

__all__ = ["Engine", "ServeConfig", "Job", "JobQueue", "MappingRequest",
           "MappingResponse", "MappingService"]
