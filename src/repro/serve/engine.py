"""Batched serving engine: prefill + decode with a fixed-size KV cache.

Implements the inference side of the framework: a request batch is
prefilled through ``prefill`` (scored prompt, cache primed), then tokens
are emitted with the jitted single-token ``serve_step``. Greedy or
temperature sampling; per-sequence stop handling via an active mask
(continuous-batching-lite: finished slots keep decoding but their tokens
are masked out — slot recycling is the host loop's job).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_lib
from repro.launch.sharding import cache_specs, param_specs, to_shardings
from repro.models import model_zoo
from repro.models.common import ModelConfig

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 => greedy
    eos_id: Optional[int] = None
    seed: int = 0


class Engine:
    """Batched LM inference: jitted prefill + single-token decode loop
    with greedy or temperature sampling (module docstring)."""

    def __init__(self, cfg: ModelConfig, params: PyTree, mesh=None,
                 scfg: Optional[ServeConfig] = None):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.mesh = mesh
        self.params = params
        self._decode = jax.jit(steps_lib.make_decode_step(cfg))
        self._prefill = jax.jit(
            steps_lib.make_prefill_step(cfg, self.scfg.max_seq))

    def generate(self, prompts: np.ndarray,
                 frames: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts [B, S_prompt] int32 -> [B, max_new_tokens]."""
        scfg = self.scfg
        b = prompts.shape[0]
        batch = {"tokens": jnp.asarray(prompts)}
        if self.cfg.family == "audio":
            batch["frames"] = jnp.asarray(frames)
        logits, cache = self._prefill(self.params, batch)

        rng = jax.random.PRNGKey(scfg.seed)
        out = np.zeros((b, scfg.max_new_tokens), np.int32)
        done = np.zeros((b,), bool)
        tok = self._sample(logits, rng)
        for i in range(scfg.max_new_tokens):
            out[:, i] = np.where(done, scfg.eos_id or 0,
                                 np.asarray(tok))
            if scfg.eos_id is not None:
                done |= np.asarray(tok) == scfg.eos_id
                if done.all():
                    break
            logits, cache = self._decode(self.params, cache, tok)
            rng, sub = jax.random.split(rng)
            tok = self._sample(logits, sub)
        return out

    def _sample(self, logits, rng):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.scfg.temperature, axis=-1).astype(
                jnp.int32)
