"""Mapping-as-a-service: deployment requests answered by the DSE stack.

The paper's pitch is that overlap-driven search is fast enough to use
*on demand*; NicePIM/PIMSYN frame the same capability as a
deployment-time service — "best PIM config for this network under this
budget". ``MappingService`` is that service: a ``MappingRequest``
(network, arch family, objective, optional area budget and wall-clock
deadline) in, a ``MappingResponse`` (the best (arch, mapping) pair plus
the full latency/energy/area Pareto frontier) out. Both dataclasses
round-trip through plain dicts/JSON; ``benchmarks/run.py serve-dse`` is
the in-process client and ``repro.serve.transport`` puts the same wire
forms behind HTTP (``run.py serve-http``). See DESIGN.md Sections 11
and 13.

Three layers make repeat traffic cheap:

* **Response memo** — an exact repeat of a completed request (same
  ``cache_key``) returns the stored ``MappingResponse`` without
  touching the queue. The memo (and the materialized loop-nest cache)
  is LRU-bounded and optionally persisted to ``persist_dir`` so a
  restarted server answers yesterday's traffic without re-sweeping.
* **Run journal** — all sweeps share one content-keyed ``RunJournal``
  (keys embed network/mode/strategy/seed/search budget/arch, so
  heterogeneous requests coexist in one store). A warm request — after
  a restart, from a second service instance on the same path, or a
  *bigger-budget* variant of an earlier request — re-proposes its
  points and serves every already-scored one from the journal with
  zero new mapping searches.
* **Request coalescing** — concurrent identical requests attach to one
  in-flight job (``repro.serve.jobs``) and share a single sweep.

Below the caches, serial sweeps share one long-lived ``OverlapEngine``
(LRU-capped at ``engine_bundle_cap`` arch bundles), so *different*
requests in the same arch family warm each other's ``PerfCache`` and
overlap tables across requests — the cross-request analogue of the
paper's within-search reuse.

Admission control (``max_pending``): once that many distinct requests
are waiting for a worker, further non-coalescing submissions are shed
with ``QueueFull`` (HTTP 429 at the transport) and counted under
``serve.shed`` — bounded queues with explicit load-shed, per the
MLPerf offline-serving discipline, instead of an unbounded backlog.

Determinism: sweeps are seed-deterministic and journal records are
content-keyed, so the same request always yields a byte-identical
``frontier_json`` (the ``ParetoFrontier.canonical_json`` artifact) —
whether scored fresh, replayed from the journal, memoized, or
coalesced. Deadline requests truncate a deterministic evaluation
order, so their frontiers converge to the full-budget answer as the
journal warms; deadline-truncated responses are never memoized.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from .. import obs
from ..obs import Registry
from ..obs.flight import FlightRecorder
from ..obs.window import SLOTracker, WindowHistogram
from ..core.engine import OverlapEngine
from ..core.search import combine_objective
from ..dse.driver import (JOURNAL_ROOT, execute_sweep, frontier_points,
                          sweep_summary)
from ..dse.explore import DSEConfig, DSEResult
from ..dse.persist import RunJournal
from ..dse.space import ParamSpace, get_space
from .jobs import Job, JobQueue, QueueFull


@dataclasses.dataclass(frozen=True)
class MappingRequest:
    """One deployment request: "best (arch, mapping) for this network".

    The scoring-relevant fields mirror ``DSEConfig``; on top of them
    ``area_budget_mm2`` constrains the winner (iso-area deployment),
    ``deadline_s`` bounds the request's wall clock (best-so-far answer),
    ``distributed`` fans the sweep out over N local worker processes,
    and ``include_mapping`` materializes the winning arch's per-layer
    loop nests into the response (one extra deterministic mapping
    search the first time a winner is seen — cached per winning arch
    afterwards, shared across requests; it runs *after* the sweep, so
    it is not bounded by ``deadline_s`` and not counted in
    ``evaluated``)."""

    network: str
    family: str = "dram_pim"
    mode: str = "transform"
    strategy: str = "forward"
    objective: str = "latency"
    blend_alpha: float = 0.5
    explorer: str = "evolve"
    budget: int = 16
    seed: int = 1
    n_candidates: int = 8
    max_steps: int = 2048
    area_budget_mm2: Optional[float] = None
    deadline_s: Optional[float] = None
    distributed: int = 0
    include_mapping: bool = False

    def __post_init__(self):
        self.dse_config()   # delegate field validation to DSEConfig
        from ..core.interface import known_network
        if not known_network(self.network):
            raise ValueError(
                f"unknown network {self.network!r}: not a core network "
                "and not a zoo scenario "
                "('<arch>[:phase][@length][xblocks]', e.g. "
                "'deepseek_moe_16b:prefill@2048')")
        if self.deadline_s is not None and self.deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")
        if self.deadline_s is not None and self.distributed:
            raise ValueError("deadline_s is serial-only; drop it or "
                             "drop distributed")

    def dse_config(self) -> DSEConfig:
        """The sweep this request asks for (journal-less: the service
        supplies its own shared journal)."""
        return DSEConfig(
            family=self.family, network=self.network, mode=self.mode,
            strategy=self.strategy, explorer=self.explorer,
            budget=self.budget, seed=self.seed,
            n_candidates=self.n_candidates, max_steps=self.max_steps,
            objective=self.objective, blend_alpha=self.blend_alpha)

    def to_dict(self) -> Dict:
        """Plain-dict wire form (JSON-safe)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "MappingRequest":
        """Inverse of ``to_dict``; unknown keys are an error (a typo'd
        constraint silently ignored would be a wrong deployment)."""
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - fields)
        if unknown:
            raise ValueError(f"unknown request fields: {unknown}")
        return cls(**d)

    def cache_key(self) -> str:
        """Content identity of the request — the memo/coalescing key.
        Every field enters (two requests differing only in deadline or
        response shape must not share a memoized response)."""
        blob = json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha1(blob.encode()).hexdigest()


@dataclasses.dataclass
class MappingResponse:
    """The service's answer: winner, baseline, frontier, provenance.

    ``best`` is the full evaluation record of the chosen (arch, mapping)
    pair — ``None`` with ``status="infeasible"`` when no scored point
    fits ``area_budget_mm2``. ``frontier_json`` is the canonical
    frontier serialization (byte-identical across repeats — THE
    determinism artifact); ``served_from`` records how the answer was
    produced (``search`` / ``journal`` / ``memo``); ``summary`` is the
    ``sweep_summary`` dict minus ``frontier_points``, which is carried
    once, top-level.

    Provenance counts the work done for *this* answer: a memo replay
    reports ``evaluated=0``, ``from_journal=0`` and ``wall_s=0.0`` —
    the replay cost nothing — while the frontier/winner payload stays
    byte-identical to the originating response."""

    request_key: str
    status: str                       # "ok" | "infeasible"
    network: str
    family: str
    objective: str
    best: Optional[Dict]
    baseline: Dict
    frontier_points: List[Dict]
    frontier_json: str
    summary: Dict
    evaluated: int
    from_journal: int
    proposed: int
    deadline_hit: bool
    wall_s: float
    served_from: str
    mapping: Optional[List[Dict]] = None

    def to_dict(self) -> Dict:
        """Plain-dict wire form (JSON-safe)."""
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = None) -> str:
        """JSON wire form of ``to_dict``."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent)

    @classmethod
    def from_dict(cls, d: Dict) -> "MappingResponse":
        """Inverse of ``to_dict`` — HTTP clients and the persisted-memo
        reload path; unknown keys are an error so schema drift between
        a persisted memo and the running code surfaces loudly."""
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - fields)
        if unknown:
            raise ValueError(f"unknown response fields: {unknown}")
        return cls(**d)


class _LRU:
    """Tiny bounded least-recently-used map (``get`` refreshes recency,
    ``put`` evicts the oldest entries past ``cap``). Not itself locked —
    the service touches it only under its own ``_lock``."""

    def __init__(self, cap: int):
        self.cap = max(1, int(cap))
        self._d: "OrderedDict[str, Any]" = OrderedDict()

    def get(self, key: str, default=None):
        """Value for ``key`` (refreshing its recency) or ``default``."""
        if key not in self._d:
            return default
        self._d.move_to_end(key)
        return self._d[key]

    def put(self, key: str, value) -> None:
        """Insert/overwrite ``key``, evicting the LRU tail past cap."""
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.cap:
            self._d.popitem(last=False)

    def items(self) -> List[Tuple[str, Any]]:
        """Snapshot of (key, value) pairs, oldest first."""
        return list(self._d.items())

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: str) -> bool:
        return key in self._d


class MappingService:
    """Request/response engine over the DSE stack (module docstring).

    One instance owns one ``RunJournal`` (``journal_path``; in-memory
    when None — tests, throwaway services), an LRU response memo
    (``memo_cap``) and loop-nest cache (``nest_cap``), a shared serial
    ``OverlapEngine`` capped at ``engine_bundle_cap`` arch bundles, and
    a staged ``JobQueue`` of ``max_workers`` sweep threads admitting at
    most ``max_pending`` waiting requests (None = unbounded; beyond it
    ``submit`` raises ``QueueFull``). ``space_overrides`` maps family
    names to caller-built ``ParamSpace``s (restricted search spaces,
    tests); families not overridden resolve through
    ``repro.dse.space.get_space``. ``shared_root`` hosts the
    per-request shared directories of ``distributed`` requests (each
    request key gets its own, so concurrent distributed sweeps never
    share a STOP file, while identical re-requests reuse their shards).
    ``persist_dir`` write-throughs the memo and nest caches to JSONL so
    a restart starts warm; ``compact_every_s`` runs ``compact()`` (the
    journal and both persisted caches) on a background cadence.

    Observability (purely observational — DESIGN.md Sections 12/14):
    ``flight_cap`` bounds the per-request flight-recorder ring (0
    disables it), with full detail retained for requests slower than
    ``slow_threshold_s``; ``window_s`` sizes the sliding window behind
    the recent-latency p50/p99 gauges (0 disables); ``slo_target_s``
    (when set) tracks an availability SLO at ``slo_goal`` — per-request
    ok/breach counters plus a windowed burn-rate gauge."""

    def __init__(self, journal_path: Optional[str] = None,
                 journal: Optional[RunJournal] = None,
                 max_workers: int = 1,
                 space_overrides: Optional[Dict[str, ParamSpace]] = None,
                 shared_root: Optional[str] = None,
                 max_pending: Optional[int] = None,
                 memo_cap: int = 256,
                 nest_cap: int = 256,
                 persist_dir: Optional[str] = None,
                 compact_every_s: Optional[float] = None,
                 engine_bundle_cap: int = 8,
                 flight_cap: int = 256,
                 slow_threshold_s: float = 1.0,
                 window_s: float = 60.0,
                 slo_target_s: Optional[float] = None,
                 slo_goal: float = 0.99):
        assert journal_path is None or journal is None, \
            "pass a journal_path or a journal, not both"
        self.journal = journal if journal is not None \
            else RunJournal(journal_path)
        self.shared_root = shared_root or os.path.join(
            JOURNAL_ROOT, "service_shared")
        self._spaces = dict(space_overrides or {})
        self._memo: _LRU = _LRU(memo_cap)
        # materialized loop nests, keyed by the winning record's journal
        # content key — deterministic, so one search serves every
        # request (deadline repeats, warm restarts) that picks the same
        # (network, search config, arch) winner
        self._mappings: _LRU = _LRU(nest_cap)
        self._persist_dir = persist_dir
        # service metrics live in the process-global registry when
        # telemetry is enabled at construction time, else in a private
        # one — either way the ``stats`` property always counts
        self._reg: Registry = obs.registry() or Registry()
        # _lock guards every piece of cross-request mutable state the
        # worker threads share: the memo, the nest cache, the journal's
        # compound check-then-record in _absorb, and the persist files
        self._lock = threading.Lock()
        # the shared serial-sweep engine is NOT thread-safe; sweeps and
        # nest materialization take _engine_lock for their whole run
        # (scoring is GIL-bound, so serializing it costs little and the
        # cross-request PerfCache warming is worth far more)
        self._engine = OverlapEngine()
        self._engine_lock = threading.Lock()
        self.engine_bundle_cap = engine_bundle_cap
        # flight recorder + sliding windows: observational only — no
        # request-path code reads them, so any setting produces
        # byte-identical responses (pinned by the determinism tests)
        self.flight = FlightRecorder(cap=flight_cap,
                                     slow_threshold_s=slow_threshold_s)
        self._window = WindowHistogram(window_s=window_s) \
            if window_s and window_s > 0 else None
        self._slo = SLOTracker(slo_target_s, goal=slo_goal,
                               window_s=window_s or 60.0) \
            if slo_target_s is not None else None
        self._load_persisted()
        self._queue = JobQueue(
            max_workers=max_workers, max_pending=max_pending,
            depth_gauge=self._reg.gauge("serve.queue.depth"))
        self.compact_every_s = compact_every_s
        self._stop = threading.Event()
        self._compactor: Optional[threading.Thread] = None
        if compact_every_s is not None and compact_every_s > 0:
            self._compactor = threading.Thread(
                target=self._compact_loop, daemon=True,
                name="mapping-compact")
            self._compactor.start()

    @property
    def stats(self) -> Dict[str, int]:
        """Legacy counter view (requests / memo_hits / coalesced /
        sweeps / shed) backed by the ``serve.*`` registry counters."""
        c = self._reg.counter
        return {"requests": int(c("serve.requests").value),
                "memo_hits": int(c("serve.memo_hits").value),
                "coalesced": int(c("serve.coalesced").value),
                "sweeps": int(c("serve.sweeps").value),
                "shed": int(c("serve.shed").value)}

    def metrics_snapshot(self) -> Dict:
        """Full snapshot of the service's metrics registry (counters,
        queue-depth gauge, request-latency histogram), refreshed with
        the sliding-window recent-latency gauges and the SLO burn rate
        (computed at scrape time, not on the request path), plus the
        flight-recorder ring under the ``"flight"`` key (ignored by
        ``render_prometheus``; rendered by ``render_report``)."""
        self._publish_window_gauges()
        snap = self._reg.snapshot()
        if self.flight.enabled:
            snap["flight"] = self.flight.snapshot()
        return snap

    def _publish_window_gauges(self) -> None:
        if self._window is not None:
            g = self._reg.gauge
            g("serve.request_seconds.window.count").set(
                float(self._window.count()))
            g("serve.request_seconds.window.p50").set(
                self._window.quantile(0.50))
            g("serve.request_seconds.window.p99").set(
                self._window.quantile(0.99))
        if self._slo is not None:
            self._reg.gauge("serve.slo.burn_rate").set(
                self._slo.burn_rate())
            self._reg.gauge("serve.slo.target_s").set(self._slo.target_s)

    def _observe_request(self, dur_s: float) -> None:
        """One per-submission latency observation, fanned out to the
        all-time histogram, the sliding window, and the SLO tracker."""
        self._reg.histogram("serve.request_seconds").observe(dur_s)
        if self._window is not None:
            self._window.observe(dur_s)
        if self._slo is not None:
            self._slo.observe(dur_s)
            self._reg.counter(
                "serve.slo.ok" if dur_s <= self._slo.target_s
                else "serve.slo.breach").inc()

    @property
    def registry(self) -> Registry:
        """The registry this service counts into (the process-global
        one when telemetry was enabled at construction, else private);
        ``GET /v1/metrics`` renders a snapshot of it."""
        return self._reg

    # -- client surface -----------------------------------------------------

    def submit(self, req: MappingRequest) -> Job:
        """Enqueue a request; returns immediately with a ``Job`` whose
        ``result()`` is the ``MappingResponse``. Memoized requests get
        a pre-completed job; identical in-flight requests coalesce
        (exempt from admission control). Raises ``QueueFull`` — after
        counting the arrival under ``serve.shed`` — when ``max_pending``
        distinct requests are already waiting."""
        key = req.cache_key()
        t0 = time.perf_counter()
        self._reg.counter("serve.requests").inc()
        with self._lock:
            memo = self._memo.get(key)
        if memo is not None:
            self._reg.counter("serve.memo_hits").inc()
            self._reg.counter("serve.served_from.memo").inc()
            dur = time.perf_counter() - t0
            self._observe_request(dur)
            # provenance counts work done for THIS answer: a replay
            # evaluated nothing and took no wall clock
            resp = dataclasses.replace(
                memo, served_from="memo", evaluated=0, from_journal=0,
                wall_s=0.0)
            self.flight.record(self._flight_rec(
                req, key, served_from="memo", outcome="ok",
                status=resp.status, total_s=dur, resp=resp))
            return Job.completed(key, resp)
        extra: Dict[str, Any] = {}
        try:
            job, coalesced = self._queue.submit(
                key, lambda: self._run(req, key, t0, extra))
        except QueueFull:
            self._reg.counter("serve.shed").inc()
            self.flight.record(self._flight_rec(
                req, key, served_from="shed", outcome="shed",
                status="shed", total_s=time.perf_counter() - t0))
            raise
        if coalesced:
            self._reg.counter("serve.coalesced").inc()
            self._reg.counter("serve.served_from.coalesced").inc()
            # the originating submission's t0 flows through _run; this
            # attachment records its own wait so coalesced waiters are
            # visible in the latency histogram too
            def _on_done(done_job: Job, _t0: float = t0) -> None:
                dur = time.perf_counter() - _t0
                self._observe_request(dur)
                self.flight.record(self._flight_rec(
                    req, key, served_from="coalesced",
                    outcome="error" if done_job.status == "failed"
                    else "ok",
                    status="error" if done_job.status == "failed"
                    else "ok",
                    admit_wait_s=dur, total_s=dur))
            job.add_done_callback(_on_done)
        else:
            job.add_done_callback(
                lambda done_job: self._flight_finish(req, key, done_job,
                                                     extra))
        return job

    def request(self, req: MappingRequest,
                timeout: Optional[float] = None) -> MappingResponse:
        """Blocking convenience: ``submit(req).result(timeout)``."""
        return self.submit(req).result(timeout)

    def compact(self) -> None:
        """One maintenance pass: compact the journal's backing store
        and rewrite the persisted memo/nest files to their live LRU
        contents (dropping evicted and superseded lines). Safe to call
        concurrently with serving; counted under ``serve.compactions``."""
        self.journal.compact()
        with self._lock:
            if self._persist_dir is not None:
                self._rewrite_jsonl(
                    self._memo_path(),
                    [{"key": k, "resp": r.to_dict()}
                     for k, r in self._memo.items()])
                self._rewrite_jsonl(
                    self._nests_path(),
                    [{"key": k, "mapping": m}
                     for k, m in self._mappings.items()])
        self._reg.counter("serve.compactions").inc()

    def close(self) -> None:
        """Drain in-flight sweeps, stop the worker and maintenance
        threads, and publish the engine's final counter deltas."""
        self._stop.set()
        if self._compactor is not None:
            self._compactor.join()
            self._compactor = None
        self._queue.shutdown(wait=True)
        self._engine.publish_metrics(self._reg)

    # -- internals ----------------------------------------------------------

    def _space(self, family: str) -> ParamSpace:
        return self._spaces.get(family) or get_space(family)

    def _flight_rec(self, req: MappingRequest, key: str, *,
                    served_from: str, outcome: str, status: str,
                    admit_wait_s: float = 0.0, evaluate_s: float = 0.0,
                    respond_s: float = 0.0, total_s: float = 0.0,
                    resp: Optional[MappingResponse] = None) -> Dict:
        """One compact flight record (``obs.flight.CORE_FIELDS``)."""
        rec = {"key": key, "network": req.network, "family": req.family,
               "objective": req.objective, "served_from": served_from,
               "outcome": outcome, "status": status,
               "admit_wait_s": admit_wait_s, "evaluate_s": evaluate_s,
               "respond_s": respond_s, "total_s": total_s,
               "evaluated": 0, "from_journal": 0, "proposed": 0,
               "deadline_hit": False}
        if resp is not None:
            rec.update(evaluated=resp.evaluated,
                       from_journal=resp.from_journal,
                       proposed=resp.proposed,
                       deadline_hit=resp.deadline_hit)
        return rec

    def _flight_finish(self, req: MappingRequest, key: str, job: Job,
                       extra: Dict) -> None:
        """Done-callback for fresh (non-coalesced) jobs: turn the job's
        stage timestamps into one flight record. By construction
        ``admit_wait + evaluate + respond == t_finish - t_submit``; the
        published ``serve.request_seconds`` observation happens at the
        end of ``_run`` (the evaluate stage), so it equals
        admit_wait + evaluate up to the submit-side epsilon — respond
        is the documented slack (DESIGN.md Section 14)."""
        ts, te0 = job.t_submit, job.t_eval_start
        te1, tf = job.t_eval_end, job.t_finish
        admit = (te0 - ts) if ts is not None and te0 is not None else 0.0
        evaluate = (te1 - te0) \
            if te0 is not None and te1 is not None else 0.0
        respond = (tf - te1) if te1 is not None and tf is not None else 0.0
        total = (tf - ts) if ts is not None and tf is not None else 0.0
        resp: Optional[MappingResponse] = None
        err: Optional[str] = None
        if job.status == "failed":
            try:
                job.result(timeout=0)
            except BaseException as e:   # the job's stored exception
                err = f"{type(e).__name__}: {e}"
        else:
            resp = job._result
        rec = self._flight_rec(
            req, key,
            served_from=resp.served_from if resp is not None else "error",
            outcome="ok" if err is None else "error",
            status=resp.status if resp is not None else "error",
            admit_wait_s=admit, evaluate_s=evaluate, respond_s=respond,
            total_s=total, resp=resp)
        detail: Dict[str, Any] = {"request": req.to_dict()}
        if err is not None:
            detail["error"] = err
        if resp is not None:
            detail["summary"] = resp.summary
            detail["wall_s"] = resp.wall_s
            detail["frontier_size"] = len(resp.frontier_points)
        if extra.get("engine_delta") is not None:
            detail["engine_delta"] = extra["engine_delta"]
        self.flight.record(rec, detail)

    def _run(self, req: MappingRequest, key: str,
             t0: Optional[float] = None,
             extra: Optional[Dict] = None) -> MappingResponse:
        self._reg.counter("serve.sweeps").inc()
        with obs.span("serve.request", network=req.network,
                      family=req.family, budget=req.budget):
            cfg = req.dse_config()
            if req.distributed > 0:
                if req.family in self._spaces:
                    raise ValueError("space_overrides are serial-only "
                                     "(spaces do not pickle to workers)")
                res = execute_sweep(
                    cfg, distributed=req.distributed,
                    shared_dir=os.path.join(self.shared_root, key[:16]))
                self._absorb(res)
            else:
                # the shared engine retains this family's arch bundles
                # (and the content-keyed PerfCache), so the next
                # same-family request starts warm; the LRU cap keeps a
                # many-tenant server's memory bounded
                with self._engine_lock:
                    before = dict(self._engine.stats)
                    res = execute_sweep(
                        cfg, space=self._space(req.family),
                        journal=self.journal,
                        deadline_s=req.deadline_s,
                        engine=self._engine)
                    self._engine.evict_lru(self.engine_bundle_cap)
                    # publish inside the lock so the before/after stats
                    # diff is this sweep's alone (publish folds the
                    # PerfCache hit/miss totals into ``stats`` first)
                    self._engine.publish_metrics(self._reg)
                    after = dict(self._engine.stats)
                if extra is not None:
                    extra["engine_delta"] = {
                        k: after[k] - before.get(k, 0)
                        for k in sorted(after)
                        if after[k] != before.get(k, 0)}
            resp = self._respond(req, key, res)
        # deadline-truncated answers are NOT memoized: a repeat must
        # re-run (replaying the journal prefix near-free) so repeated
        # deadline requests make monotone progress toward the
        # full-budget frontier instead of freezing at the first cut
        if not resp.deadline_hit:
            with self._lock:
                self._memo.put(key, resp)
                self._append_jsonl(self._memo_path(),
                                   {"key": key, "resp": resp.to_dict()})
        self._reg.counter("serve.served_from." + resp.served_from).inc()
        if t0 is not None:
            self._observe_request(time.perf_counter() - t0)
        return resp

    def _absorb(self, res: DSEResult) -> None:
        """Merge a distributed sweep's records into the service journal
        so later serial requests reuse them (records carry their
        content key; re-absorbing an existing key is skipped to keep
        the journal file from accreting duplicates). Runs under the
        service lock: the contains-then-record pair must be atomic
        against other workers absorbing overlapping result sets."""
        with self._lock:
            for rec in res.records:
                if rec["key"] not in self.journal:
                    self.journal.record(rec["key"], rec)
            self.journal.publish()

    def _best(self, req: MappingRequest, res: DSEResult) -> Optional[Dict]:
        """The winning record: lowest search-objective value, restricted
        to the area budget when one is given (None if nothing fits).
        The objective is recomputed from each record's latency/energy —
        never read from a stored ``objective_value`` — so records
        journaled under an older schema (or a different objective) rank
        correctly for THIS request's objective."""
        eligible = res.records
        if req.area_budget_mm2 is not None:
            eligible = [r for r in eligible
                        if r["area_mm2"] <= req.area_budget_mm2 + 1e-12]
        return min(eligible,
                   key=lambda r: combine_objective(
                       req.objective, r["total_ns"], r["energy_pj"],
                       req.blend_alpha),
                   default=None)

    def _respond(self, req: MappingRequest, key: str,
                 res: DSEResult) -> MappingResponse:
        best = self._best(req, res)
        mapping = None
        if req.include_mapping and best is not None:
            with self._lock:
                mapping = self._mappings.get(best["key"])
            if mapping is None:
                # materialization runs unlocked (it is a real mapping
                # search); a racing worker may do the same search, but
                # both produce the identical deterministic nest
                mapping = self._materialize_mapping(req, best)
                with self._lock:
                    self._mappings.put(best["key"], mapping)
                    self._append_jsonl(self._nests_path(),
                                       {"key": best["key"],
                                        "mapping": mapping})
        # the frontier is carried once, top-level; the summary keeps
        # every other sweep_summary column (the BENCH-compatible shape)
        summary = dict(sweep_summary(res))
        pts = summary.pop("frontier_points")
        return MappingResponse(
            request_key=key,
            status="ok" if best is not None else "infeasible",
            network=req.network, family=req.family,
            objective=req.objective,
            best=best, baseline=res.baseline,
            frontier_points=pts,
            frontier_json=res.frontier.canonical_json(),
            summary=summary,
            evaluated=int(res.stats["evaluated"]),
            from_journal=int(res.stats["from_journal"]),
            proposed=int(res.stats["proposed"]),
            deadline_hit=bool(res.stats.get("deadline_hit", False)),
            wall_s=float(res.stats["wall_s"]),
            served_from="journal" if res.stats["evaluated"] == 0
            else "search",
            mapping=mapping)

    def _materialize_mapping(self, req: MappingRequest,
                             best: Dict) -> List[Dict]:
        """Re-derive the winner's per-layer loop nests. Deterministic —
        the same search that scored the record — so the nests *are* the
        scored mapping; costs one extra mapping search on a cold
        request (the memo answers repeats). Runs on the shared engine:
        the sweep that just crowned this winner left its arch bundle
        and perf entries warm."""
        from ..core.engine import optimize_network_engine
        from ..core.interface import describe
        space = self._space(req.family)
        arch = space.build(space.point(**best["point"]))
        desc = describe(req.network)
        cfg = req.dse_config()
        with self._engine_lock:
            net = optimize_network_engine(desc.layers, desc.edges, arch,
                                          cfg.search_config(),
                                          engine=self._engine)
            self._engine.evict_lru(self.engine_bundle_cap)
        return [
            {"layer": getattr(lr.mapping.layer, "name", f"layer{i}"),
             "nest": lr.mapping.pretty(),
             "latency_ns": float(lr.latency_ns),
             "energy_pj": float(lr.energy_pj),
             "transformed": bool(lr.transformed),
             "moved_frac": float(lr.moved_frac)}
            for i, lr in enumerate(net.layers)]

    # -- persistence --------------------------------------------------------

    def _memo_path(self) -> Optional[str]:
        return None if self._persist_dir is None \
            else os.path.join(self._persist_dir, "memo.jsonl")

    def _nests_path(self) -> Optional[str]:
        return None if self._persist_dir is None \
            else os.path.join(self._persist_dir, "nests.jsonl")

    def _append_jsonl(self, path: Optional[str], entry: Dict) -> None:
        """Write-through one cache entry (no-op without persist_dir).
        Callers hold ``_lock``, so appends never interleave."""
        if path is None:
            return
        with open(path, "a", encoding="utf-8") as fh:
            fh.write(json.dumps(entry, sort_keys=True,
                                separators=(",", ":")) + "\n")

    @staticmethod
    def _rewrite_jsonl(path: Optional[str], entries: List[Dict]) -> None:
        """Atomically replace a persist file with the live entries."""
        if path is None:
            return
        tmp = path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            for entry in entries:
                fh.write(json.dumps(entry, sort_keys=True,
                                    separators=(",", ":")) + "\n")
        os.replace(tmp, path)

    def _load_persisted(self) -> None:
        """Reload the memo and nest caches from ``persist_dir`` (append
        order = recency order, later lines win, so replaying into the
        LRU keeps exactly the ``cap`` most recent entries)."""
        if self._persist_dir is None:
            return
        os.makedirs(self._persist_dir, exist_ok=True)
        for path, lru, decode in (
                (self._memo_path(), self._memo,
                 lambda e: MappingResponse.from_dict(e["resp"])),
                (self._nests_path(), self._mappings,
                 lambda e: e["mapping"])):
            if not os.path.exists(path):
                continue
            with open(path, "r", encoding="utf-8") as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        entry = json.loads(line)
                        lru.put(entry["key"], decode(entry))
                    except (ValueError, KeyError, TypeError):
                        # a torn tail (crash mid-append) or a
                        # stale-schema line loses one cache entry, not
                        # the server start; compact() rewrites it away
                        continue

    def _compact_loop(self) -> None:
        while not self._stop.wait(self.compact_every_s):
            self.compact()
