"""Mapping-as-a-service: deployment requests answered by the DSE stack.

The paper's pitch is that overlap-driven search is fast enough to use
*on demand*; NicePIM/PIMSYN frame the same capability as a
deployment-time service — "best PIM config for this network under this
budget". ``MappingService`` is that service, HTTP-less by design: a
``MappingRequest`` (network, arch family, objective, optional area
budget and wall-clock deadline) in, a ``MappingResponse`` (the best
(arch, mapping) pair plus the full latency/energy/area Pareto
frontier) out. Transport is someone else's problem — both dataclasses
round-trip through plain dicts/JSON, and ``benchmarks/run.py
serve-dse`` is the local client. See DESIGN.md Section 11.

Three layers make repeat traffic cheap:

* **Response memo** — an exact repeat of a completed request (same
  ``cache_key``) returns the stored ``MappingResponse`` without
  touching the queue.
* **Run journal** — all sweeps share one content-keyed ``RunJournal``
  (keys embed network/mode/strategy/seed/search budget/arch, so
  heterogeneous requests coexist in one store). A warm request — after
  a restart, from a second service instance on the same path, or a
  *bigger-budget* variant of an earlier request — re-proposes its
  points and serves every already-scored one from the journal with
  zero new mapping searches.
* **Request coalescing** — concurrent identical requests attach to one
  in-flight job (``repro.serve.jobs``) and share a single sweep.

Determinism: sweeps are seed-deterministic and journal records are
content-keyed, so the same request always yields a byte-identical
``frontier_json`` (the ``ParetoFrontier.canonical_json`` artifact) —
whether scored fresh, replayed from the journal, or coalesced.
Deadline requests truncate a deterministic evaluation order, so their
frontiers converge to the full-budget answer as the journal warms.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from typing import Dict, List, Optional

from .. import obs
from ..obs import Registry
from ..dse.driver import (JOURNAL_ROOT, execute_sweep, frontier_points,
                          sweep_summary)
from ..dse.explore import DSEConfig, DSEResult
from ..dse.persist import RunJournal
from ..dse.space import ParamSpace, get_space
from .jobs import Job, JobQueue


@dataclasses.dataclass(frozen=True)
class MappingRequest:
    """One deployment request: "best (arch, mapping) for this network".

    The scoring-relevant fields mirror ``DSEConfig``; on top of them
    ``area_budget_mm2`` constrains the winner (iso-area deployment),
    ``deadline_s`` bounds the request's wall clock (best-so-far answer),
    ``distributed`` fans the sweep out over N local worker processes,
    and ``include_mapping`` materializes the winning arch's per-layer
    loop nests into the response (one extra deterministic mapping
    search the first time a winner is seen — cached per winning arch
    afterwards, shared across requests; it runs *after* the sweep, so
    it is not bounded by ``deadline_s`` and not counted in
    ``evaluated``)."""

    network: str
    family: str = "dram_pim"
    mode: str = "transform"
    strategy: str = "forward"
    objective: str = "latency"
    blend_alpha: float = 0.5
    explorer: str = "evolve"
    budget: int = 16
    seed: int = 1
    n_candidates: int = 8
    max_steps: int = 2048
    area_budget_mm2: Optional[float] = None
    deadline_s: Optional[float] = None
    distributed: int = 0
    include_mapping: bool = False

    def __post_init__(self):
        self.dse_config()   # delegate field validation to DSEConfig
        if self.deadline_s is not None and self.deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")
        if self.deadline_s is not None and self.distributed:
            raise ValueError("deadline_s is serial-only; drop it or "
                             "drop distributed")

    def dse_config(self) -> DSEConfig:
        """The sweep this request asks for (journal-less: the service
        supplies its own shared journal)."""
        return DSEConfig(
            family=self.family, network=self.network, mode=self.mode,
            strategy=self.strategy, explorer=self.explorer,
            budget=self.budget, seed=self.seed,
            n_candidates=self.n_candidates, max_steps=self.max_steps,
            objective=self.objective, blend_alpha=self.blend_alpha)

    def to_dict(self) -> Dict:
        """Plain-dict wire form (JSON-safe)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "MappingRequest":
        """Inverse of ``to_dict``; unknown keys are an error (a typo'd
        constraint silently ignored would be a wrong deployment)."""
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - fields)
        if unknown:
            raise ValueError(f"unknown request fields: {unknown}")
        return cls(**d)

    def cache_key(self) -> str:
        """Content identity of the request — the memo/coalescing key.
        Every field enters (two requests differing only in deadline or
        response shape must not share a memoized response)."""
        blob = json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha1(blob.encode()).hexdigest()


@dataclasses.dataclass
class MappingResponse:
    """The service's answer: winner, baseline, frontier, provenance.

    ``best`` is the full evaluation record of the chosen (arch, mapping)
    pair — ``None`` with ``status="infeasible"`` when no scored point
    fits ``area_budget_mm2``. ``frontier_json`` is the canonical
    frontier serialization (byte-identical across repeats — THE
    determinism artifact); ``served_from`` records how the answer was
    produced (``search`` / ``journal`` / ``memo``); ``summary`` is the
    ``sweep_summary`` dict minus ``frontier_points``, which is carried
    once, top-level."""

    request_key: str
    status: str                       # "ok" | "infeasible"
    network: str
    family: str
    objective: str
    best: Optional[Dict]
    baseline: Dict
    frontier_points: List[Dict]
    frontier_json: str
    summary: Dict
    evaluated: int
    from_journal: int
    proposed: int
    deadline_hit: bool
    wall_s: float
    served_from: str
    mapping: Optional[List[Dict]] = None

    def to_dict(self) -> Dict:
        """Plain-dict wire form (JSON-safe)."""
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = None) -> str:
        """JSON wire form of ``to_dict``."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent)


class MappingService:
    """Request/response engine over the DSE stack (module docstring).

    One instance owns one ``RunJournal`` (``journal_path``; in-memory
    when None — tests, throwaway services), a response memo, and a
    ``JobQueue`` of ``max_workers`` sweep threads. ``space_overrides``
    maps family names to caller-built ``ParamSpace``s (restricted
    search spaces, tests); families not overridden resolve through
    ``repro.dse.space.get_space``. ``shared_root`` hosts the per-request
    shared directories of ``distributed`` requests (each request key
    gets its own, so concurrent distributed sweeps never share a STOP
    file, while identical re-requests reuse their shards)."""

    def __init__(self, journal_path: Optional[str] = None,
                 journal: Optional[RunJournal] = None,
                 max_workers: int = 1,
                 space_overrides: Optional[Dict[str, ParamSpace]] = None,
                 shared_root: Optional[str] = None):
        assert journal_path is None or journal is None, \
            "pass a journal_path or a journal, not both"
        self.journal = journal if journal is not None \
            else RunJournal(journal_path)
        self.shared_root = shared_root or os.path.join(
            JOURNAL_ROOT, "service_shared")
        self._spaces = dict(space_overrides or {})
        self._memo: Dict[str, MappingResponse] = {}
        # materialized loop nests, keyed by the winning record's journal
        # content key — deterministic, so one search serves every
        # request (deadline repeats, warm restarts) that picks the same
        # (network, search config, arch) winner
        self._mappings: Dict[str, List[Dict]] = {}
        # service metrics live in the process-global registry when
        # telemetry is enabled at construction time, else in a private
        # one — either way the ``stats`` property always counts
        self._reg: Registry = obs.registry() or Registry()
        self._queue = JobQueue(
            max_workers=max_workers,
            depth_gauge=self._reg.gauge("serve.queue.depth"))
        self._lock = threading.Lock()

    @property
    def stats(self) -> Dict[str, int]:
        """Legacy counter view (requests / memo_hits / coalesced /
        sweeps) backed by the ``serve.*`` registry counters."""
        c = self._reg.counter
        return {"requests": int(c("serve.requests").value),
                "memo_hits": int(c("serve.memo_hits").value),
                "coalesced": int(c("serve.coalesced").value),
                "sweeps": int(c("serve.sweeps").value)}

    def metrics_snapshot(self) -> Dict:
        """Full snapshot of the service's metrics registry (counters,
        queue-depth gauge, request-latency histogram)."""
        return self._reg.snapshot()

    # -- client surface -----------------------------------------------------

    def submit(self, req: MappingRequest) -> Job:
        """Enqueue a request; returns immediately with a ``Job`` whose
        ``result()`` is the ``MappingResponse``. Memoized requests get
        a pre-completed job; identical in-flight requests coalesce."""
        key = req.cache_key()
        t0 = time.perf_counter()
        self._reg.counter("serve.requests").inc()
        with self._lock:
            memo = self._memo.get(key)
        if memo is not None:
            self._reg.counter("serve.memo_hits").inc()
            self._reg.counter("serve.served_from.memo").inc()
            self._reg.histogram("serve.request_seconds").observe(
                time.perf_counter() - t0)
            return Job.completed(key, dataclasses.replace(
                memo, served_from="memo"))
        job, coalesced = self._queue.submit(
            key, lambda: self._run(req, key, t0))
        if coalesced:
            self._reg.counter("serve.coalesced").inc()
        return job

    def request(self, req: MappingRequest,
                timeout: Optional[float] = None) -> MappingResponse:
        """Blocking convenience: ``submit(req).result(timeout)``."""
        return self.submit(req).result(timeout)

    def close(self) -> None:
        """Drain in-flight sweeps and stop the worker threads."""
        self._queue.shutdown(wait=True)

    # -- internals ----------------------------------------------------------

    def _space(self, family: str) -> ParamSpace:
        return self._spaces.get(family) or get_space(family)

    def _run(self, req: MappingRequest, key: str,
             t0: Optional[float] = None) -> MappingResponse:
        self._reg.counter("serve.sweeps").inc()
        with obs.span("serve.request", network=req.network,
                      family=req.family, budget=req.budget):
            cfg = req.dse_config()
            if req.distributed > 0:
                if req.family in self._spaces:
                    raise ValueError("space_overrides are serial-only "
                                     "(spaces do not pickle to workers)")
                res = execute_sweep(
                    cfg, distributed=req.distributed,
                    shared_dir=os.path.join(self.shared_root, key[:16]))
                self._absorb(res)
            else:
                res = execute_sweep(cfg, space=self._space(req.family),
                                    journal=self.journal,
                                    deadline_s=req.deadline_s)
            resp = self._respond(req, key, res)
        # deadline-truncated answers are NOT memoized: a repeat must
        # re-run (replaying the journal prefix near-free) so repeated
        # deadline requests make monotone progress toward the
        # full-budget frontier instead of freezing at the first cut
        if not resp.deadline_hit:
            with self._lock:
                self._memo[key] = resp
        self._reg.counter("serve.served_from." + resp.served_from).inc()
        if t0 is not None:
            self._reg.histogram("serve.request_seconds").observe(
                time.perf_counter() - t0)
        return resp

    def _absorb(self, res: DSEResult) -> None:
        """Merge a distributed sweep's records into the service journal
        so later serial requests reuse them (records carry their
        content key; re-absorbing an existing key is skipped to keep
        the journal file from accreting duplicates)."""
        for rec in res.records:
            if rec["key"] not in self.journal:
                self.journal.record(rec["key"], rec)
        self.journal.publish()

    def _best(self, req: MappingRequest, res: DSEResult) -> Optional[Dict]:
        """The winning record: lowest search-objective value, restricted
        to the area budget when one is given (None if nothing fits)."""
        eligible = res.records
        if req.area_budget_mm2 is not None:
            eligible = [r for r in eligible
                        if r["area_mm2"] <= req.area_budget_mm2 + 1e-12]
        return min(eligible,
                   key=lambda r: r.get("objective_value", r["total_ns"]),
                   default=None)

    def _respond(self, req: MappingRequest, key: str,
                 res: DSEResult) -> MappingResponse:
        best = self._best(req, res)
        mapping = None
        if req.include_mapping and best is not None:
            mapping = self._mappings.get(best["key"])
            if mapping is None:
                mapping = self._materialize_mapping(req, best)
                self._mappings[best["key"]] = mapping
        # the frontier is carried once, top-level; the summary keeps
        # every other sweep_summary column (the BENCH-compatible shape)
        summary = dict(sweep_summary(res))
        pts = summary.pop("frontier_points")
        return MappingResponse(
            request_key=key,
            status="ok" if best is not None else "infeasible",
            network=req.network, family=req.family,
            objective=req.objective,
            best=best, baseline=res.baseline,
            frontier_points=pts,
            frontier_json=res.frontier.canonical_json(),
            summary=summary,
            evaluated=int(res.stats["evaluated"]),
            from_journal=int(res.stats["from_journal"]),
            proposed=int(res.stats["proposed"]),
            deadline_hit=bool(res.stats.get("deadline_hit", False)),
            wall_s=float(res.stats["wall_s"]),
            served_from="journal" if res.stats["evaluated"] == 0
            else "search",
            mapping=mapping)

    def _materialize_mapping(self, req: MappingRequest,
                             best: Dict) -> List[Dict]:
        """Re-derive the winner's per-layer loop nests. Deterministic —
        the same search that scored the record — so the nests *are* the
        scored mapping; costs one extra mapping search on a cold
        request (the memo answers repeats)."""
        from ..core.engine import optimize_network_engine
        from ..core.interface import describe
        space = self._space(req.family)
        arch = space.build(space.point(**best["point"]))
        desc = describe(req.network)
        cfg = req.dse_config()
        net = optimize_network_engine(desc.layers, desc.edges, arch,
                                      cfg.search_config())
        return [
            {"layer": getattr(lr.mapping.layer, "name", f"layer{i}"),
             "nest": lr.mapping.pretty(),
             "latency_ns": float(lr.latency_ns),
             "energy_pj": float(lr.energy_pj),
             "transformed": bool(lr.transformed),
             "moved_frac": float(lr.moved_frac)}
            for i, lr in enumerate(net.layers)]
