"""HTTP/JSON transport for the mapping service (stdlib-only).

``MappingHTTPServer`` puts ``MappingService`` behind a
``ThreadingHTTPServer`` speaking the exact wire forms the service
already defines — ``MappingRequest.from_dict`` in,
``MappingResponse.to_json`` out — so the in-process client
(``run.py serve-dse``), the HTTP client (``run.py serve-http`` + curl)
and the tests all exercise one schema. No third-party web framework:
the repo's no-new-dependencies rule holds, and ``http.server`` is
plenty for a request/response service whose unit of work is a mapping
sweep, not a byte shuffle.

Routes (DESIGN.md Section 13):

* ``POST /v1/mapping`` — body is a ``MappingRequest`` dict; answers
  200 with the ``MappingResponse`` JSON. Malformed JSON or an invalid
  request field is a 400 with ``{"error": ...}``; admission-control
  shed is a 429 with a ``Retry-After`` hint; an internal failure is a
  500 carrying the exception text.
* ``GET /v1/metrics`` — the service registry in Prometheus text
  exposition format (``repro.obs.render_prometheus``).
* ``GET /v1/healthz`` — liveness: ``{"status": "ok"}`` plus queue
  depth, always 200 while the process serves.
* ``GET /v1/debug/requests`` — the flight recorder's recent ring,
  newest first (``?limit=N`` caps the list, ``?slow=1`` reads the
  full-detail slow ring); 404 when the recorder is disabled
  (``flight_cap=0``).
* ``GET /v1/debug/requests/<key>`` — the fullest record held for one
  request key (prefix match, so the first 8–12 hex chars of a
  ``request_key`` suffice); 404 when unknown.

Determinism over the wire: responses are rendered with
``to_json(indent=None, sort_keys)`` — the same canonical serialization
the in-process path produces — so a repeated request's body (memo
replay included) is byte-identical except for its provenance fields,
and ``frontier_json`` is byte-identical, full stop.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlsplit

from ..obs import render_prometheus
from .jobs import QueueFull, QueueShutdown
from .service import MappingRequest, MappingService

#: Retry-After hint (seconds) sent with 429 shed responses
RETRY_AFTER_S = 1

#: request bodies past this are refused outright (a MappingRequest is
#: a few hundred bytes; anything bigger is a client bug or abuse)
MAX_BODY_BYTES = 1 << 20


class _Handler(BaseHTTPRequestHandler):
    """One HTTP exchange; the service lives on ``self.server``."""

    # ThreadingHTTPServer default (HTTP/1.0) closes per request; 1.1
    # keeps benchmark client connections alive across the storm
    protocol_version = "HTTP/1.1"

    def log_message(self, format, *args):  # noqa: A002 - stdlib signature
        """Silence per-request stderr lines (telemetry supersedes)."""

    def _send(self, code: int, body: bytes,
              content_type: str = "application/json",
              retry_after: Optional[int] = None) -> None:
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        if retry_after is not None:
            self.send_header("Retry-After", str(retry_after))
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, code: int, obj,
                   retry_after: Optional[int] = None) -> None:
        self._send(code, (json.dumps(obj, sort_keys=True) + "\n").encode(),
                   retry_after=retry_after)

    def do_GET(self):  # noqa: N802 - stdlib handler name
        """Route GETs: metrics, healthz, debug/requests, else 404."""
        svc = self.server.service
        parts = urlsplit(self.path)
        path = parts.path
        if path == "/v1/metrics":
            self._send(200,
                       render_prometheus(svc.metrics_snapshot()).encode(),
                       content_type="text/plain; version=0.0.4")
        elif path == "/v1/healthz":
            self._send_json(200, {
                "status": "ok",
                "inflight": svc._queue.inflight(),
                "pending": svc._queue.pending()})
        elif path == "/v1/debug/requests":
            if not svc.flight.enabled:
                self._send_json(404, {"error": "flight recorder disabled "
                                               "(flight_cap=0)"})
                return
            q = parse_qs(parts.query)
            try:
                limit = int(q["limit"][0]) if "limit" in q else None
            except ValueError:
                self._send_json(400, {"error": "limit must be an int"})
                return
            slow_only = q.get("slow", ["0"])[0] not in ("0", "", "false")
            recs = svc.flight.snapshot(limit=limit, slow_only=slow_only)
            self._send_json(200, {"requests": recs, "count": len(recs)})
        elif path.startswith("/v1/debug/requests/"):
            if not svc.flight.enabled:
                self._send_json(404, {"error": "flight recorder disabled "
                                               "(flight_cap=0)"})
                return
            key = path[len("/v1/debug/requests/"):]
            rec = svc.flight.get(key)
            if rec is None:
                self._send_json(404,
                                {"error": f"no flight record for {key!r}"})
            else:
                self._send_json(200, rec)
        else:
            self._send_json(404, {"error": f"no route {self.path}"})

    def do_POST(self):  # noqa: N802 - stdlib handler name
        """Route POSTs: /v1/mapping, else 404."""
        if self.path != "/v1/mapping":
            self._send_json(404, {"error": f"no route {self.path}"})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
        except ValueError:
            n = -1
        if n < 0 or n > MAX_BODY_BYTES:
            self._send_json(400, {"error": "bad Content-Length"})
            return
        try:
            req = MappingRequest.from_dict(json.loads(self.rfile.read(n)))
        except (ValueError, TypeError) as e:
            # covers malformed JSON, unknown fields, and every
            # validation error MappingRequest raises itself
            self._send_json(400, {"error": str(e)})
            return
        try:
            resp = self.server.service.request(req)
        except QueueFull as e:
            self._send_json(429, {"error": f"shed: {e}"},
                            retry_after=RETRY_AFTER_S)
            return
        except QueueShutdown as e:
            self._send_json(503, {"error": str(e)})
            return
        except Exception as e:   # a sweep failure is the server's bug
            self._send_json(500, {"error": f"{type(e).__name__}: {e}"})
            return
        self._send(200, (resp.to_json() + "\n").encode())


class MappingHTTPServer:
    """A ``MappingService`` bound to a listening HTTP socket.

    Owns the ``ThreadingHTTPServer`` and its accept loop thread;
    ``port=0`` binds an ephemeral port (tests, parallel CI) readable
    back from ``.port`` once constructed. The caller owns the service's
    lifecycle: ``close()`` stops accepting, then drains the service.

    Usage::

        svc = MappingService(journal_path=..., max_pending=32)
        server = MappingHTTPServer(svc, host="127.0.0.1", port=8099)
        server.start()          # returns immediately
        ...
        server.close()          # stop accepting, drain sweeps
    """

    def __init__(self, service: MappingService,
                 host: str = "127.0.0.1", port: int = 0):
        self.service = service
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        # hand the service to handlers through the server object —
        # BaseHTTPRequestHandler instances are constructed per request
        self._httpd.service = service
        self._thread: Optional[threading.Thread] = None

    @property
    def host(self) -> str:
        """Bound host of the listening socket."""
        return self._httpd.server_address[0]

    @property
    def port(self) -> int:
        """Bound port (the OS's pick when constructed with port=0)."""
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        """Base URL of the server, e.g. ``http://127.0.0.1:8099``."""
        return f"http://{self.host}:{self.port}"

    def start(self) -> "MappingHTTPServer":
        """Start the accept loop on a daemon thread; returns self."""
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.1},
            daemon=True, name="mapping-http")
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        """Run the accept loop on the calling thread (the CLI path)."""
        self._httpd.serve_forever(poll_interval=0.1)

    def close(self) -> None:
        """Stop accepting connections, join the accept thread, close
        the socket, and drain the service's in-flight sweeps."""
        self._httpd.shutdown()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._httpd.server_close()
        self.service.close()
