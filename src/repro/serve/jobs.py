"""Async job queue with request coalescing for the mapping service.

A ``Job`` is one unit of background work identified by a content key;
a ``JobQueue`` runs jobs on a small thread pool and **coalesces**
submissions: while a job for key K is in flight (queued or running),
every further ``submit`` with key K attaches to the same ``Job`` object
instead of enqueueing duplicate work — N concurrent identical
deployment requests cost one sweep. Once a job finishes it leaves the
in-flight table; whether a *later* identical submission re-runs is the
caller's concern (the mapping service answers it from its response
memo and the run journal, so the re-run costs zero mapping searches).

Threads, not processes: a DSE sweep is numpy/pure-Python compute that
the service runs at most ``max_workers`` at a time, and results are
plain dicts shared by reference. For process-scale parallelism the
service dispatches through the distributed sweep subsystem instead
(``repro.dse.distrib``).
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, Optional

#: job lifecycle states (``Job.status``)
PENDING, RUNNING, DONE, FAILED = "pending", "running", "done", "failed"


class Job:
    """Handle on one submitted unit of work.

    ``result(timeout)`` blocks until completion and returns the value
    (re-raising the job's exception if it failed); ``done()`` polls.
    ``n_attached`` counts how many submissions this job absorbed — 1
    for a lone request, more when concurrent identical requests were
    coalesced onto it."""

    def __init__(self, key: str):
        self.key = key
        self.status = PENDING
        self.n_attached = 1
        self._event = threading.Event()
        self._result: Any = None
        self._exc: Optional[BaseException] = None

    @classmethod
    def completed(cls, key: str, result: Any) -> "Job":
        """A pre-finished job (memo hits: the answer already exists)."""
        job = cls(key)
        job._finish(result=result)
        return job

    def done(self) -> bool:
        """True once the job has finished (successfully or not)."""
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until the job finishes; return its value or re-raise
        its exception. Raises ``TimeoutError`` on expiry."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"job {self.key} not done in {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def _finish(self, result: Any = None,
                exc: Optional[BaseException] = None) -> None:
        self._result = result
        self._exc = exc
        self.status = FAILED if exc is not None else DONE
        self._event.set()


class JobQueue:
    """Keyed thread-pool executor with in-flight coalescing."""

    def __init__(self, max_workers: int = 1, depth_gauge=None):
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="mapping-job")
        self._lock = threading.Lock()
        self._inflight: Dict[str, Job] = {}
        self.n_submitted = 0
        self.n_coalesced = 0
        # optional ``repro.obs`` Gauge tracking the in-flight depth
        # (set under the queue lock on every enqueue/finish)
        self._depth_gauge = depth_gauge

    def submit(self, key: str, fn: Callable[[], Any]) -> "tuple[Job, bool]":
        """Enqueue ``fn`` under ``key``; returns ``(job, coalesced)``.
        An in-flight job with the same key is returned (``coalesced``
        True) instead of enqueueing a duplicate — ``fn`` is then never
        called. The flag is this call's own outcome, so callers never
        have to read the shared counters racily."""
        with self._lock:
            self.n_submitted += 1
            job = self._inflight.get(key)
            if job is not None:
                job.n_attached += 1
                self.n_coalesced += 1
                return job, True
            job = Job(key)
            self._inflight[key] = job
            if self._depth_gauge is not None:
                self._depth_gauge.set(len(self._inflight))
        try:
            self._pool.submit(self._run, job, fn)
        except BaseException as e:
            # e.g. submit after shutdown: never leak an unfinishable
            # PENDING job that later identical submits would hang on
            with self._lock:
                self._inflight.pop(key, None)
            job._finish(exc=e)
            raise
        return job, False

    def inflight(self) -> int:
        """How many distinct keys are currently queued or running."""
        with self._lock:
            return len(self._inflight)

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting work and (optionally) drain running jobs."""
        self._pool.shutdown(wait=wait)

    def _run(self, job: Job, fn: Callable[[], Any]) -> None:
        job.status = RUNNING
        try:
            result = fn()
        except BaseException as e:  # surfaced via Job.result
            job._finish(exc=e)
        else:
            job._finish(result=result)
        finally:
            # drop from the table only after the result is readable, so
            # a racing submit either coalesces onto a finished job
            # (result() returns immediately) or starts a fresh one
            with self._lock:
                self._inflight.pop(job.key, None)
                if self._depth_gauge is not None:
                    self._depth_gauge.set(len(self._inflight))
