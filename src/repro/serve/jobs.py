"""Staged job queue with request coalescing and admission control.

A ``Job`` is one unit of background work identified by a content key;
a ``JobQueue`` runs jobs through three dedicated stages joined by
bounded queues — the MLPerf offline-serving discipline, where one slow
stage backpressures its upstream instead of stalling the rest:

* **admit** — runs on the *caller's* thread inside ``submit``: coalesce
  onto an in-flight job for the same key, or append to the bounded
  pending queue. Once ``max_pending`` distinct jobs are waiting, admit
  refuses with ``QueueFull`` (the service maps this to an HTTP 429 and
  a ``serve.shed`` counter) — an explicit load-shed answer instead of
  an unbounded thread-pool backlog.
* **evaluate** — ``max_workers`` dedicated threads pop pending jobs and
  run their callables. Results go into a *bounded* respond queue, so a
  slow respond stage backpressures evaluation rather than piling up
  unfinished results.
* **respond** — one dedicated thread finishes each job (storing the
  result, waking waiters, firing done-callbacks) and only *then* drops
  it from the in-flight table, so a racing submit either coalesces onto
  a finished job (``result()`` returns immediately) or starts fresh.

Coalescing: while a job for key K is in flight (queued or running),
every further ``submit`` with key K attaches to the same ``Job`` object
instead of enqueueing duplicate work — N concurrent identical
deployment requests cost one sweep. Once a job finishes it leaves the
in-flight table; whether a *later* identical submission re-runs is the
caller's concern (the mapping service answers it from its response
memo and the run journal, so the re-run costs zero mapping searches).

Threads, not processes: a DSE sweep is numpy/pure-Python compute that
the service runs at most ``max_workers`` at a time, and results are
plain dicts shared by reference. For process-scale parallelism the
service dispatches through the distributed sweep subsystem instead
(``repro.dse.distrib``).
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

#: job lifecycle states (``Job.status``)
PENDING, RUNNING, DONE, FAILED = "pending", "running", "done", "failed"

#: respond-queue sentinel that stops the responder thread
_STOP = object()


class QueueFull(RuntimeError):
    """Admission refused: the pending queue is at ``max_pending``.

    The load-shed signal of the serving stack — callers answer it
    immediately (HTTP transports as a 429) instead of queueing
    unboundedly. Coalescing submissions are never shed: attaching to an
    in-flight job costs no queue slot."""


class QueueShutdown(RuntimeError):
    """The queue no longer accepts work (``shutdown`` was called)."""


class Job:
    """Handle on one submitted unit of work.

    ``result(timeout)`` blocks until completion and returns the value
    (re-raising the job's exception if it failed); ``done()`` polls.
    ``n_attached`` counts how many submissions this job absorbed — 1
    for a lone request, more when concurrent identical requests were
    coalesced onto it. ``add_done_callback`` registers a callable fired
    exactly once with the job after it finishes (immediately if it
    already has) — the service records per-submission latency through
    it, so coalesced waiters are not invisible to the histograms.

    Stage timestamps (``time.perf_counter`` values, set by the queue's
    stage threads; ``None`` until the stage is reached) let the flight
    recorder attribute a request's wall clock to its pipeline stages:
    ``t_submit`` (admitted to the pending queue), ``t_eval_start`` /
    ``t_eval_end`` (the evaluate stage ran the callable), ``t_finish``
    (the respond stage made the result readable). They are telemetry —
    nothing in the queue branches on them."""

    def __init__(self, key: str):
        self.key = key
        self.status = PENDING
        self.n_attached = 1
        self.t_submit: Optional[float] = None
        self.t_eval_start: Optional[float] = None
        self.t_eval_end: Optional[float] = None
        self.t_finish: Optional[float] = None
        self._event = threading.Event()
        self._result: Any = None
        self._exc: Optional[BaseException] = None
        self._cb_lock = threading.Lock()
        self._cbs: List[Callable[["Job"], None]] = []

    @classmethod
    def completed(cls, key: str, result: Any) -> "Job":
        """A pre-finished job (memo hits: the answer already exists)."""
        job = cls(key)
        job._finish(result=result)
        return job

    def done(self) -> bool:
        """True once the job has finished (successfully or not)."""
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until the job finishes; return its value or re-raise
        its exception. Raises ``TimeoutError`` on expiry."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"job {self.key} not done in {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def add_done_callback(self, cb: Callable[["Job"], None]) -> None:
        """Run ``cb(job)`` once the job finishes — immediately when it
        already has. Callbacks fire on the respond thread (or the
        registering thread for already-finished jobs) and must not
        block."""
        with self._cb_lock:
            if not self._event.is_set():
                self._cbs.append(cb)
                return
        cb(self)

    def _finish(self, result: Any = None,
                exc: Optional[BaseException] = None) -> None:
        if self.t_finish is None:
            self.t_finish = time.perf_counter()
        self._result = result
        self._exc = exc
        self.status = FAILED if exc is not None else DONE
        self._event.set()
        with self._cb_lock:
            cbs, self._cbs = self._cbs, []
        for cb in cbs:
            cb(self)


class JobQueue:
    """Keyed staged executor: bounded admit -> evaluate -> respond."""

    def __init__(self, max_workers: int = 1,
                 max_pending: Optional[int] = None,
                 depth_gauge=None):
        self._lock = threading.Lock()
        self._have_work = threading.Condition(self._lock)
        self._pending: Deque[Tuple[Job, Callable[[], Any]]] = deque()
        self._inflight: Dict[str, Job] = {}
        self._closed = False
        self.max_pending = max_pending
        self.n_submitted = 0
        self.n_coalesced = 0
        self.n_shed = 0
        # optional ``repro.obs`` Gauge tracking the in-flight depth
        # (set under the queue lock on every enqueue/finish)
        self._depth_gauge = depth_gauge
        # evaluate -> respond: bounded so a stalled responder
        # backpressures the evaluate stage instead of hoarding results
        self._respond_q: "queue.Queue" = queue.Queue(
            maxsize=max(2, 2 * max_workers))
        self._workers = [
            threading.Thread(target=self._worker_loop, daemon=True,
                             name=f"mapping-job-{i}")
            for i in range(max(1, max_workers))]
        self._responder = threading.Thread(
            target=self._respond_loop, daemon=True, name="mapping-respond")
        for t in self._workers:
            t.start()
        self._responder.start()

    def submit(self, key: str, fn: Callable[[], Any]) -> "tuple[Job, bool]":
        """Enqueue ``fn`` under ``key``; returns ``(job, coalesced)``.
        An in-flight job with the same key is returned (``coalesced``
        True) instead of enqueueing a duplicate — ``fn`` is then never
        called, and coalescing is exempt from admission control. A
        fresh key is refused with ``QueueFull`` once ``max_pending``
        jobs are already waiting, and with ``QueueShutdown`` after
        ``shutdown`` — the flag/exception is this call's own outcome,
        so callers never have to read the shared counters racily."""
        with self._lock:
            self.n_submitted += 1
            job = self._inflight.get(key)
            if job is not None:
                job.n_attached += 1
                self.n_coalesced += 1
                return job, True
            if self._closed:
                raise QueueShutdown("submit after shutdown")
            if (self.max_pending is not None
                    and len(self._pending) >= self.max_pending):
                self.n_shed += 1
                raise QueueFull(
                    f"{len(self._pending)} jobs pending >= "
                    f"max_pending={self.max_pending}")
            job = Job(key)
            job.t_submit = time.perf_counter()
            self._inflight[key] = job
            self._pending.append((job, fn))
            self._set_depth_locked()
            self._have_work.notify()
        return job, False

    def inflight(self) -> int:
        """How many distinct keys are currently queued or running."""
        with self._lock:
            return len(self._inflight)

    def pending(self) -> int:
        """How many admitted jobs are waiting for an evaluate thread
        (the quantity ``max_pending`` bounds)."""
        with self._lock:
            return len(self._pending)

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting work. ``wait=True`` drains every admitted job
        (pending and running) and joins the stage threads; ``wait=False``
        fails still-pending jobs with ``QueueShutdown`` — the
        ``_finish(exc=...)`` path, so their waiters unblock instead of
        hanging — and leaves running jobs to finish on the daemon
        threads."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            cancelled = []
            if not wait:
                cancelled = list(self._pending)
                self._pending.clear()
            self._have_work.notify_all()
        for job, _fn in cancelled:
            job._finish(exc=QueueShutdown(
                "job queue shut down before the job ran"))
            with self._lock:
                self._inflight.pop(job.key, None)
                self._set_depth_locked()
        if wait:
            for t in self._workers:
                t.join()
            self._respond_q.put(_STOP)
            self._responder.join()

    # -- stage threads ------------------------------------------------------

    def _set_depth_locked(self) -> None:
        if self._depth_gauge is not None:
            self._depth_gauge.set(len(self._inflight))

    def _worker_loop(self) -> None:
        while True:
            with self._lock:
                while not self._pending and not self._closed:
                    self._have_work.wait()
                if not self._pending:   # closed and drained
                    return
                job, fn = self._pending.popleft()
                job.status = RUNNING
            job.t_eval_start = time.perf_counter()
            try:
                result, exc = fn(), None
            except BaseException as e:  # surfaced via Job.result
                result, exc = None, e
            job.t_eval_end = time.perf_counter()
            # bounded: blocks (backpressure) when the responder lags
            self._respond_q.put((job, result, exc))

    def _respond_loop(self) -> None:
        while True:
            item = self._respond_q.get()
            if item is _STOP:
                return
            job, result, exc = item
            job._finish(result=result, exc=exc)
            # drop from the table only after the result is readable, so
            # a racing submit either coalesces onto a finished job
            # (result() returns immediately) or starts a fresh one
            with self._lock:
                self._inflight.pop(job.key, None)
                self._set_depth_locked()
