"""granite-moe-1b-a400m — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024, 16H (GQA kv=8), expert d_ff=512, vocab=49155.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite_moe_1b_a400m", family="moe",
        n_layers=24, d_model=1024, vocab=49155,
        n_heads=16, n_kv_heads=8, d_ff=512,
        n_experts=32, top_k=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite_moe_1b_a400m_smoke", family="moe",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, d_ff=64,
        n_experts=4, top_k=2,
    )
