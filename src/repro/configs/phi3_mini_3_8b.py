"""phi3-mini-3.8b — RoPE SwiGLU GQA [arXiv:2404.14219].

32L d_model=3072, 32H (GQA kv=32), d_ff=8192, vocab=32064.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="phi3_mini_3_8b", family="dense",
        n_layers=32, d_model=3072, vocab=32064,
        n_heads=32, n_kv_heads=32, d_ff=8192,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="phi3_mini_3_8b_smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, d_ff=128,
    )
