"""deepseek-moe-16b — 2 shared + 64 routed top-6, fine-grained experts
[arXiv:2401.06066].

28L d_model=2048, 16H (GQA kv=16), expert d_ff=1408, vocab=102400.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek_moe_16b", family="moe",
        n_layers=28, d_model=2048, vocab=102400,
        n_heads=16, n_kv_heads=16, d_ff=1408,
        n_experts=64, top_k=6, n_shared_experts=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek_moe_16b_smoke", family="moe",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, d_ff=64,
        n_experts=4, top_k=2, n_shared_experts=1,
    )
