"""Architecture registry: the 10 assigned configs + input-shape cells.

Every config cites its public source (see per-file docstrings). Use
``get_config(arch_id)`` for the full config and
``get_config(arch_id, smoke=True)`` for the reduced same-family smoke
config exercised by CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.common import ModelConfig

ARCH_IDS = (
    "mamba2_780m",
    "zamba2_1_2b",
    "granite_moe_1b_a400m",
    "deepseek_moe_16b",
    "olmo_1b",
    "phi3_mini_3_8b",
    "stablelm_3b",
    "granite_8b",
    "whisper_base",
    "llava_next_34b",
)

# dashed aliases as listed in the assignment
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SHAPE_NAMES = tuple(SHAPES)

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (DESIGN.md Section 4); skips are part of the 40-cell accounting.
_LONG_OK = ("ssm", "hybrid")


def cell_status(arch_id: str, shape: str) -> Tuple[bool, str]:
    cfg = get_config(arch_id)
    if shape == "long_500k" and cfg.family not in _LONG_OK:
        return False, ("skip: full-attention arch — 500k context needs "
                       "sub-quadratic attention (run for ssm/hybrid only)")
    return True, "run"


def cells(include_skipped: bool = False) -> List[Tuple[str, str]]:
    out = []
    for a in ARCH_IDS:
        for s in SHAPE_NAMES:
            ok, _ = cell_status(a, s)
            if ok or include_skipped:
                out.append((a, s))
    return out


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    arch_id = ALIASES.get(arch_id, arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; have {list(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.smoke_config() if smoke else mod.config()
