"""olmo-1b — non-parametric LayerNorm [arXiv:2402.00838].

16L d_model=2048, 16H (GQA kv=16), d_ff=8192, vocab=50304.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="olmo_1b", family="dense",
        n_layers=16, d_model=2048, vocab=50304,
        n_heads=16, n_kv_heads=16, d_ff=8192,
        norm="layernorm_np",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="olmo_1b_smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, d_ff=128,
        norm="layernorm_np",
    )
