"""stablelm-3b [hf:stabilityai/stablelm-2-1_6b family].

32L d_model=2560, 32H (GQA kv=32), d_ff=6912, vocab=50304.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="stablelm_3b", family="dense",
        n_layers=32, d_model=2560, vocab=50304,
        n_heads=32, n_kv_heads=32, d_ff=6912,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="stablelm_3b_smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, d_ff=128,
    )
