"""granite-8b — llama-arch code model [arXiv:2405.04324].

36L d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=49152.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite_8b", family="dense",
        n_layers=36, d_model=4096, vocab=49152,
        n_heads=32, n_kv_heads=8, d_ff=14336,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite_8b_smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, d_ff=128,
    )
