"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1536, attn-free, vocab=50280, ssm_state=128.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2_780m", family="ssm",
        n_layers=48, d_model=1536, vocab=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
        ssm_conv=4, ssm_chunk=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2_780m_smoke", family="ssm",
        n_layers=2, d_model=64, vocab=256,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_groups=1,
        ssm_conv=4, ssm_chunk=16,
    )
