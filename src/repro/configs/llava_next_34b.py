"""llava-next-34b — anyres tiling VLM backbone
[hf:llava-hf/llava-v1.6 family].

60L d_model=7168, 56H (GQA kv=8), d_ff=20480, vocab=64000. The vision
tower / anyres tiling is a STUB: ``input_specs()``/smoke tests supply
precomputed patch embeddings prepended to the token stream.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="llava_next_34b", family="vlm",
        n_layers=60, d_model=7168, vocab=64000,
        n_heads=56, n_kv_heads=8, d_ff=20480,
        head_dim=128, img_tokens=576,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="llava_next_34b_smoke", family="vlm",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, d_ff=128, img_tokens=8,
    )
