"""zamba2-1.2b — Mamba2 + shared attention blocks [arXiv:2411.15242].

38L d_model=2048, 32H (GQA kv=32), d_ff=8192, vocab=32000, ssm_state=64.
One shared attention+MLP block (shared weights) applied every 6 layers.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2_1_2b", family="hybrid",
        n_layers=38, d_model=2048, vocab=32000,
        n_heads=32, n_kv_heads=32, d_ff=8192,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
        ssm_conv=4, ssm_chunk=256, attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2_1_2b_smoke", family="hybrid",
        n_layers=4, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, d_ff=128,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_groups=1,
        ssm_conv=4, ssm_chunk=16, attn_every=2,
    )
