"""whisper-base — enc-dec, conv frontend STUB [arXiv:2212.04356].

6L (enc) + 6L (dec), d_model=512, 8H (kv=8), d_ff=2048, vocab=51865.
``input_specs()`` supplies precomputed frame embeddings (the mel+conv
frontend is stubbed per the assignment).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper_base", family="audio",
        n_layers=6, enc_layers=6, d_model=512, vocab=51865,
        n_heads=8, n_kv_heads=8, d_ff=2048, mlp="gelu",
        use_rope=False, enc_frames=1500, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper_base_smoke", family="audio",
        n_layers=2, enc_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, d_ff=128, mlp="gelu",
        use_rope=False, enc_frames=16, max_seq=64,
    )
