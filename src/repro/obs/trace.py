"""Span tracing and the process-global telemetry switch.

Telemetry is **off by default**: the module-global current telemetry
is a ``NullTelemetry`` whose ``span()``/``event()`` return shared
no-op singletons, so the disabled cost of an instrumented call site is
one dict/attribute lookup and a truthiness test. ``enable()`` swaps in
a live ``Telemetry`` (optionally with a JSONL ``TraceSink`` and a
``sample_every`` span-sampling stride); ``disable()`` restores the
null default and closes the sink.

Spans nest: each ``with obs.span("dse.sweep", budget=8):`` writes one
JSONL line at exit with the span name, wall-clock duration, nesting
depth (tracked per-thread) and any keyword attributes. Sampling is
*counter-based* (every Nth span of a given name), never RNG-based, so
tracing can never perturb the deterministic search results —
the DESIGN.md Section 12 contract.

Module-level helpers (``inc``, ``observe``, ``set_gauge``, ``event``,
``span``) always dispatch through the *current* telemetry, so call
sites instrumented at import time pick up a registry enabled later at
runtime.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, Optional

from .metrics import Registry


class TraceSink:
    """Append-only JSONL event writer (lazily opened, line-flushed)."""

    def __init__(self, path: str):
        self.path = path
        self._fh = None
        self._lock = threading.Lock()

    def write(self, ev: Dict) -> None:
        """Serialize one event dict as a JSON line and flush it."""
        line = json.dumps(ev, sort_keys=True)
        with self._lock:
            if self._fh is None:
                self._fh = open(self.path, "a", encoding="utf-8")
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        """Close the underlying file (later writes reopen it)."""
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


class _Span:
    """Context manager timing one named span; writes JSONL on exit."""

    __slots__ = ("_tel", "_name", "_attrs", "_t0", "_wall0")

    def __init__(self, tel: "Telemetry", name: str, attrs: Dict):
        self._tel = tel
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> "_Span":
        self._tel._depth().append(self._name)
        self._wall0 = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        dur = time.perf_counter() - self._t0
        stack = self._tel._depth()
        stack.pop()
        self._tel._emit_span(self._name, dur, len(stack), self._attrs,
                             self._wall0)


class _NoopSpan:
    """Shared do-nothing span for disabled/sampled-out call sites."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


class Telemetry:
    """Live telemetry: a metrics ``Registry`` plus optional span sink.

    ``sample_every=N`` keeps every Nth span per span-name (a plain
    per-name counter, deterministic across runs); metrics are never
    sampled."""

    enabled = True

    def __init__(self, registry: Optional[Registry] = None,
                 sink: Optional[TraceSink] = None,
                 sample_every: int = 1):
        self.registry = registry if registry is not None else Registry()
        self.sink = sink
        self.sample_every = max(1, int(sample_every))
        self._seen: Dict[str, int] = {}
        self._seen_lock = threading.Lock()
        self._local = threading.local()

    def _depth(self):
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def span(self, name: str, **attrs):
        """A timing context manager for ``name``; no-op when the span
        is sampled out or there is no sink (metrics still flow)."""
        if self.sink is None:
            return _NOOP_SPAN
        if self.sample_every > 1:
            with self._seen_lock:
                n = self._seen.get(name, 0)
                self._seen[name] = n + 1
            if n % self.sample_every:
                return _NOOP_SPAN
        return _Span(self, name, attrs)

    def _emit_span(self, name: str, dur_s: float, depth: int,
                   attrs: Dict, wall0: Optional[float] = None) -> None:
        # ``ts`` (end) and ``ts0`` (start) share one wall-clock base, so
        # trace analytics never reconstruct starts by mixing the
        # ``time.time`` and ``perf_counter`` bases; ``tid`` keys the
        # per-thread span streams for call-tree/Chrome-trace export.
        # Older traces lack ``ts0``/``tid`` — ``repro.obs.profile``
        # falls back to ``ts - dur_s`` and a single implicit thread.
        end = time.time()
        ev = {"ev": "span", "name": name, "ts": end,
              "ts0": wall0 if wall0 is not None else end - dur_s,
              "dur_s": dur_s, "depth": depth,
              "tid": threading.get_ident()}
        ev.update(attrs)
        self.sink.write(ev)
        self.registry.histogram("span." + name).observe(dur_s)

    def event(self, name: str, **attrs) -> None:
        """Write one point-in-time JSONL event (no-op without a sink)."""
        if self.sink is None:
            return
        ev = {"ev": "event", "name": name, "ts": time.time()}
        ev.update(attrs)
        self.sink.write(ev)


class NullTelemetry:
    """Disabled telemetry: every operation is a shared no-op."""

    enabled = False
    registry = None
    sink = None

    def span(self, name: str, **attrs):
        """Return the shared no-op span."""
        return _NOOP_SPAN

    def event(self, name: str, **attrs) -> None:
        """Drop the event."""


_NULL = NullTelemetry()
_current = _NULL


def current():
    """The process-global telemetry (``NullTelemetry`` when disabled)."""
    return _current


def enabled() -> bool:
    """True when telemetry collection is on."""
    return _current.enabled


def registry() -> Optional[Registry]:
    """The live metrics registry, or None when telemetry is disabled."""
    return _current.registry


def enable(trace_path: Optional[str] = None, sample_every: int = 1,
           registry: Optional[Registry] = None) -> Telemetry:
    """Turn telemetry on process-wide and return the live instance.

    ``trace_path`` adds a JSONL span/event sink; ``sample_every=N``
    keeps every Nth span per name; ``registry`` reuses an existing
    metrics registry (a fresh one is created otherwise)."""
    global _current
    sink = TraceSink(trace_path) if trace_path else None
    _current = Telemetry(registry=registry, sink=sink,
                         sample_every=sample_every)
    return _current


def disable() -> None:
    """Restore the no-op default and close any open trace sink."""
    global _current
    sink = getattr(_current, "sink", None)
    _current = _NULL
    if sink is not None:
        sink.close()


def inc(name: str, n: float = 1.0) -> None:
    """Increment counter ``name`` on the current registry (no-op when
    telemetry is disabled)."""
    reg = _current.registry
    if reg is not None:
        reg.counter(name).inc(n)


def observe(name: str, value: float) -> None:
    """Record ``value`` into histogram ``name`` (no-op when disabled)."""
    reg = _current.registry
    if reg is not None:
        reg.histogram(name).observe(value)


def set_gauge(name: str, value: float) -> None:
    """Set gauge ``name`` to ``value`` (no-op when disabled)."""
    reg = _current.registry
    if reg is not None:
        reg.gauge(name).set(value)


def event(name: str, **attrs) -> None:
    """Emit a point-in-time trace event through the current telemetry."""
    _current.event(name, **attrs)


def span(name: str, **attrs):
    """A span context manager through the current telemetry (a shared
    no-op object when telemetry is disabled)."""
    return _current.span(name, **attrs)
