"""Trace analytics: call trees, time attribution, flamegraph export.

Turns the span JSONL a traced run leaves behind (``--trace-out``) into
the artifacts a latency investigation actually needs:

* a **call tree** per thread, reconstructed from the spans' exit order
  and per-thread nesting depth (spans are written at *exit*, so a
  parent line always follows its children's lines);
* **self/total-time attribution** per span name — total time is the
  summed duration of every span with that name, self time is total
  minus time spent in child spans, so the self-time column answers
  "where did the milliseconds actually go" and sums exactly to the
  root spans' duration;
* the **critical path** — from the longest root span, repeatedly
  descend into the longest child — the single chain a perf fix must
  shorten to move the end-to-end number;
* **Chrome trace-event JSON** (``ph: "X"`` complete events) loadable
  in Perfetto / ``chrome://tracing``;
* **folded-stack text** (``root;child;leaf <self_us>`` lines), the
  input format of the standard flamegraph toolchain.

All surfaced as ``benchmarks/run.py obs-profile --trace <file>
[--chrome-out P] [--folded-out P] [--top N]``.

Trace-format tolerance: spans written before the start-timestamp fix
carry only the end wall clock (``ts``) — starts fall back to
``ts - dur_s`` — and no ``tid`` (all spans parse onto one implicit
thread). Unparsable lines (a truncated tail from a killed run) are
counted and skipped, never fatal; an empty or span-free trace renders
a message instead of a stack trace.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple


class SpanNode:
    """One span occurrence in the reconstructed call tree."""

    __slots__ = ("name", "ts0", "ts", "dur_s", "depth", "tid", "attrs",
                 "children")

    def __init__(self, name: str, ts0: float, ts: float, dur_s: float,
                 depth: int, tid: int, attrs: Dict):
        self.name = name
        self.ts0 = ts0
        self.ts = ts
        self.dur_s = dur_s
        self.depth = depth
        self.tid = tid
        self.attrs = attrs
        self.children: List["SpanNode"] = []

    def self_s(self) -> float:
        """Duration not attributable to any child span (floored at 0 —
        sampled-out parents can leave children summing past ``dur_s``)."""
        return max(0.0, self.dur_s - sum(c.dur_s for c in self.children))


#: span-event keys that are structural, not user attributes
_STRUCT_KEYS = frozenset(("ev", "name", "ts", "ts0", "dur_s", "depth",
                          "tid"))


class Trace:
    """A parsed span trace: the per-thread call forest plus parse stats.

    ``roots`` holds every depth-0 (or orphaned) span across all
    threads; ``n_events`` / ``n_spans`` / ``n_bad_lines`` describe what
    the file held. Empty and truncated files parse to an empty trace —
    callers render a message, not a traceback."""

    def __init__(self, roots: List[SpanNode], n_events: int,
                 n_spans: int, n_bad_lines: int):
        self.roots = roots
        self.n_events = n_events
        self.n_spans = n_spans
        self.n_bad_lines = n_bad_lines

    def total_s(self) -> float:
        """Summed duration of the root spans (the attribution base)."""
        return sum(r.dur_s for r in self.roots)

    def walk(self):
        """Yield every node, parents before children."""
        stack = list(self.roots)
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children)


def _span_node(ev: Dict) -> Optional[SpanNode]:
    try:
        name = ev["name"]
        dur = float(ev["dur_s"])
        depth = int(ev["depth"])
        ts = float(ev.get("ts", 0.0))
    except (KeyError, TypeError, ValueError):
        return None
    if dur < 0 or depth < 0:
        return None
    # pre-fix traces carry only the end wall clock: reconstruct the
    # start from the same base instead of mixing clock bases
    ts0 = float(ev.get("ts0", ts - dur))
    tid = int(ev.get("tid", 0))
    attrs = {k: v for k, v in ev.items() if k not in _STRUCT_KEYS}
    return SpanNode(name, ts0, ts, dur, depth, tid, attrs)


def parse_trace(path: str) -> Trace:
    """Parse a span JSONL file into a :class:`Trace`.

    Reconstruction: spans are written at exit, so within one thread a
    span at depth ``d`` adopts every not-yet-adopted span at depth
    ``> d`` as its children (deeper-than-``d+1`` levels only appear
    when sampling dropped the intermediate parent — they attach
    flattened rather than vanish). Spans still unadopted at EOF (their
    parent never closed, or the file was truncated) become roots.
    Malformed lines and non-span events are skipped and counted."""
    n_events = n_spans = n_bad = 0
    # per-tid: depth -> completed nodes awaiting a parent
    pending: Dict[int, Dict[int, List[SpanNode]]] = {}
    try:
        fh = open(path, "r", encoding="utf-8")
    except OSError:
        return Trace([], 0, 0, 0)
    with fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
                if not isinstance(ev, dict):
                    raise ValueError
            except ValueError:
                n_bad += 1
                continue
            n_events += 1
            if ev.get("ev") != "span":
                continue
            node = _span_node(ev)
            if node is None:
                n_bad += 1
                continue
            n_spans += 1
            by_depth = pending.setdefault(node.tid, {})
            # adopt every pending deeper span in this thread
            deeper = sorted(d for d in by_depth if d > node.depth)
            for d in deeper:
                node.children.extend(by_depth.pop(d))
            node.children.sort(key=lambda c: c.ts0)
            by_depth.setdefault(node.depth, []).append(node)
    roots: List[SpanNode] = []
    for by_depth in pending.values():
        for d in sorted(by_depth):
            roots.extend(by_depth[d])
    roots.sort(key=lambda r: r.ts0)
    return Trace(roots, n_events, n_spans, n_bad)


def attribution(trace: Trace) -> List[Dict]:
    """Per-span-name time attribution, heaviest self time first.

    Each row: ``name``, ``count``, ``total_s`` (summed durations),
    ``self_s`` (durations minus child time) and ``self_pct`` of the
    root total. Self times sum to the root spans' total duration by
    construction — the "where did it go" invariant."""
    rows: Dict[str, Dict] = {}
    for node in trace.walk():
        row = rows.setdefault(node.name, {"name": node.name, "count": 0,
                                          "total_s": 0.0, "self_s": 0.0})
        row["count"] += 1
        row["total_s"] += node.dur_s
        row["self_s"] += node.self_s()
    base = trace.total_s()
    out = sorted(rows.values(), key=lambda r: -r["self_s"])
    for row in out:
        row["self_pct"] = 100.0 * row["self_s"] / base if base > 0 else 0.0
    return out


def critical_path(trace: Trace) -> List[Dict]:
    """The longest chain: from the longest root, descend into the
    longest child at every level. Rows carry ``name``/``dur_s``/
    ``self_s``/``depth`` — the spans a fix must shorten to move the
    end-to-end wall clock."""
    if not trace.roots:
        return []
    node = max(trace.roots, key=lambda r: r.dur_s)
    path = []
    while node is not None:
        path.append({"name": node.name, "dur_s": node.dur_s,
                     "self_s": node.self_s(), "depth": node.depth})
        node = max(node.children, key=lambda c: c.dur_s, default=None)
    return path


def chrome_trace(trace: Trace) -> Dict:
    """Chrome trace-event JSON (the ``chrome://tracing`` / Perfetto
    load format): one ``ph: "X"`` complete event per span, timestamps
    in microseconds relative to the earliest span start, thread ids
    preserved, span attributes in ``args``."""
    events: List[Dict] = []
    t_base = min((n.ts0 for n in trace.walk()), default=0.0)
    for node in trace.walk():
        events.append({
            "name": node.name,
            "ph": "X",
            "ts": round((node.ts0 - t_base) * 1e6, 3),
            "dur": round(node.dur_s * 1e6, 3),
            "pid": 1,
            "tid": node.tid,
            "args": node.attrs,
        })
    events.sort(key=lambda e: (e["tid"], e["ts"]))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def folded_stacks(trace: Trace) -> List[str]:
    """Folded-stack lines (``a;b;c <self_us>``) — the collapsed input
    of the standard flamegraph toolchain; zero-self frames are kept
    only when they are leaves, so every microsecond appears exactly
    once."""
    lines: Dict[str, int] = {}

    def rec(node: SpanNode, prefix: str) -> None:
        stack = f"{prefix};{node.name}" if prefix else node.name
        self_us = int(round(node.self_s() * 1e6))
        if self_us > 0 or not node.children:
            lines[stack] = lines.get(stack, 0) + self_us
        for child in node.children:
            rec(child, stack)

    for root in trace.roots:
        rec(root, "")
    return [f"{stack} {us}" for stack, us in sorted(lines.items())]


def render_profile(trace: Trace, top: int = 15) -> str:
    """The ``obs-profile`` terminal report: parse stats, the self-time
    table (heaviest ``top`` names), and the critical path."""
    if trace.n_spans == 0:
        msg = "(no spans in trace"
        if trace.n_bad_lines:
            msg += f"; {trace.n_bad_lines} unparsable lines skipped"
        return msg + ")\n"
    lines = [f"spans={trace.n_spans} roots={len(trace.roots)} "
             f"total={trace.total_s() * 1e3:.3f}ms"
             + (f" bad_lines={trace.n_bad_lines}"
                if trace.n_bad_lines else "")]
    rows = attribution(trace)
    lines.append("")
    lines.append(f"{'name':<28} {'count':>6} {'total_ms':>10} "
                 f"{'self_ms':>10} {'self%':>6}")
    for row in rows[:top]:
        lines.append(f"{row['name']:<28} {row['count']:>6} "
                     f"{row['total_s'] * 1e3:>10.3f} "
                     f"{row['self_s'] * 1e3:>10.3f} "
                     f"{row['self_pct']:>5.1f}%")
    shown = sum(r["self_s"] for r in rows[:top])
    lines.append(f"{'(shown)':<28} {'':>6} {'':>10} "
                 f"{shown * 1e3:>10.3f} "
                 f"{100.0 * shown / trace.total_s() if trace.total_s() else 0.0:>5.1f}%")
    lines.append("")
    lines.append("critical path:")
    for step in critical_path(trace):
        indent = "  " * (step["depth"] + 1)
        lines.append(f"{indent}{step['name']}  "
                     f"{step['dur_s'] * 1e3:.3f}ms "
                     f"(self {step['self_s'] * 1e3:.3f}ms)")
    return "\n".join(lines) + "\n"


def write_chrome_trace(trace: Trace, path: str) -> None:
    """Write the Chrome trace-event JSON to ``path``."""
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(chrome_trace(trace), fh, sort_keys=True)
        fh.write("\n")


def write_folded(trace: Trace, path: str) -> None:
    """Write the folded flamegraph stacks to ``path``."""
    with open(path, "w", encoding="utf-8") as fh:
        for line in folded_stacks(trace):
            fh.write(line + "\n")
