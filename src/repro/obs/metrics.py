"""Mergeable metrics: counters, gauges, fixed-bucket histograms.

A ``Registry`` is a named, get-or-create store of the three metric
kinds. Everything here is stdlib-only and cheap enough to stay on in
production paths:

* ``Counter`` / ``Gauge`` — one float cell behind a tiny lock.
* ``Histogram`` — fixed, immutable bucket bounds chosen at creation
  (default: log-spaced seconds from 1 µs to ~100 s, ~1.47x resolution),
  so two histograms of the same metric are *mergeable* by element-wise
  addition. Percentiles (``quantile``) interpolate within the bucket.
* ``Registry.snapshot()`` — a plain JSON-safe dict; ``merge_snapshot``
  folds another process's snapshot in (counters add, gauges take the
  max, histogram counts add). This is how the distributed fleet's
  per-worker metric shards become one fleet-health view
  (``repro.dse.distrib``).
* ``render_prometheus`` — the standard text exposition
  (``repro_<name>_total`` counters, ``_bucket{le=...}`` histograms),
  so any scraper can consume a snapshot without bespoke glue.

Metric names are dotted lowercase ``subsystem.object.event`` (e.g.
``engine.tiles.hit``, ``serve.request_seconds``); the Prometheus
renderer maps dots to underscores. Determinism contract: metrics only
*observe* — no code path may branch on a metric value, so enabling or
disabling collection can never change a produced number (DESIGN.md
Section 12).
"""
from __future__ import annotations

import bisect
import re
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: default histogram bounds (seconds): log-spaced, 6 buckets per decade
#: from 1 µs to ~100 s — fine enough for p50/p99 reporting (~1.47x
#: bucket resolution) while staying mergeable across processes
DEFAULT_BOUNDS: Tuple[float, ...] = tuple(
    round(10.0 ** (e / 6.0), 12) for e in range(-36, 13))


class Counter:
    """Monotonically increasing count (float-valued for summed times)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (thread-safe)."""
        with self._lock:
            self.value += n


class Gauge:
    """Last-written instantaneous value (queue depth, bundle count)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        """Overwrite the current value (thread-safe)."""
        with self._lock:
            self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with an overflow bucket.

    Bucket ``i`` counts observations in ``(bounds[i-1], bounds[i]]``
    (the first bucket is ``(-inf, bounds[0]]``); one trailing bucket
    counts everything above ``bounds[-1]``. Bounds are immutable after
    construction, which is what makes histograms of the same metric
    mergeable across processes by adding counts element-wise."""

    __slots__ = ("name", "bounds", "counts", "total", "sum", "_lock")

    def __init__(self, name: str,
                 bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds = tuple(bounds) if bounds is not None else DEFAULT_BOUNDS
        assert list(self.bounds) == sorted(self.bounds), \
            "histogram bounds must be ascending"
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0
        self.sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        """Record one observation (thread-safe)."""
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.total += 1
            self.sum += v

    def quantile(self, q: float) -> float:
        """Approximate ``q``-quantile (linear interpolation within the
        bucket; 0.0 when empty; the last bound for overflow mass)."""
        return quantile(self.bounds, self.counts, q)


def quantile(bounds: Sequence[float], counts: Sequence[int],
             q: float) -> float:
    """``q``-quantile of a fixed-bucket histogram's counts.

    Linear interpolation inside the containing bucket (lower edge 0.0
    for the first bucket); the top bound for mass in the overflow
    bucket; 0.0 for an empty histogram."""
    total = sum(counts)
    if total <= 0:
        return 0.0
    target = q * total
    cum = 0.0
    for i, c in enumerate(counts):
        cum += c
        if cum >= target and c > 0:
            if i >= len(bounds):        # overflow bucket: no upper edge
                return float(bounds[-1])
            lo = float(bounds[i - 1]) if i > 0 else 0.0
            hi = float(bounds[i])
            frac = (target - (cum - c)) / c
            return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
    return float(bounds[-1]) if bounds else 0.0


class Registry:
    """Named get-or-create store of counters, gauges and histograms."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        """The counter named ``name`` (created on first use)."""
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        """The gauge named ``name`` (created on first use)."""
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        """The histogram named ``name`` (created on first use with the
        given bounds; later calls must not pass different bounds)."""
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, Histogram(name, bounds))
        if bounds is not None and tuple(bounds) != h.bounds:
            raise ValueError(f"histogram {name!r} already exists with "
                             "different bounds")
        return h

    def snapshot(self) -> Dict:
        """JSON-safe dict of every metric's current state."""
        with self._lock:
            counters = {n: c.value for n, c in self._counters.items()}
            gauges = {n: g.value for n, g in self._gauges.items()}
            hists = {n: {"bounds": list(h.bounds),
                         "counts": list(h.counts),
                         "count": h.total, "sum": h.sum}
                     for n, h in self._hists.items()}
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def merge_snapshot(self, snap: Dict) -> None:
        """Fold another registry's ``snapshot()`` into this one:
        counters add, gauges keep the max, histogram counts add
        (bounds must match — they do for same-named metrics created
        through this module's defaults)."""
        for n, v in (snap.get("counters") or {}).items():
            self.counter(n).inc(v)
        for n, v in (snap.get("gauges") or {}).items():
            g = self.gauge(n)
            g.set(max(g.value, v))
        for n, h in (snap.get("histograms") or {}).items():
            mine = self.histogram(n, h.get("bounds"))
            with mine._lock:
                for i, c in enumerate(h.get("counts") or []):
                    mine.counts[i] += c
                mine.total += int(h.get("count", 0))
                mine.sum += float(h.get("sum", 0.0))


def merge_snapshots(snaps: Iterable[Dict]) -> Dict:
    """Merge many ``Registry.snapshot()`` dicts into one (the fleet
    coordinator's view over per-worker metric shards)."""
    reg = Registry()
    for s in snaps:
        if s:
            reg.merge_snapshot(s)
    return reg.snapshot()


def _prom_name(name: str) -> str:
    return "repro_" + re.sub(r"[^a-zA-Z0-9_]", "_", name)


def escape_label_value(value: str) -> str:
    """Escape one label value per the Prometheus text exposition format:
    backslash, double-quote and newline must be escaped inside the
    quoted value (``\\\\``, ``\\"``, ``\\n``) — anything else through a
    scraper unescaped silently corrupts the series."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _label_str(labels: Optional[Dict[str, str]],
               extra: Optional[Tuple[str, str]] = None) -> str:
    """Render a ``{k="v",...}`` label block (empty string when none).
    Values pass through ``escape_label_value``; the ``extra`` pair (the
    histogram ``le`` bound, already exposition-safe) renders last."""
    pairs = [(k, escape_label_value(v))
             for k, v in sorted((labels or {}).items())]
    if extra is not None:
        pairs.append(extra)
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


def render_prometheus(snap: Dict,
                      labels: Optional[Dict[str, str]] = None) -> str:
    """Prometheus text exposition of one ``Registry.snapshot()``.

    ``labels`` attaches constant labels (e.g. ``{"instance": ...}``) to
    every emitted series, values escaped per the exposition format.
    Histograms emit the full conformant series set: cumulative
    ``_bucket{le=...}`` lines, a ``+Inf`` bucket equal to ``_count``,
    and the ``_sum``/``_count`` pair."""
    out: List[str] = []
    base = _label_str(labels)
    for n in sorted(snap.get("counters") or {}):
        pn = _prom_name(n)
        out.append(f"# TYPE {pn}_total counter")
        out.append(f"{pn}_total{base} {snap['counters'][n]:g}")
    for n in sorted(snap.get("gauges") or {}):
        pn = _prom_name(n)
        out.append(f"# TYPE {pn} gauge")
        out.append(f"{pn}{base} {snap['gauges'][n]:g}")
    for n in sorted(snap.get("histograms") or {}):
        h = snap["histograms"][n]
        pn = _prom_name(n)
        out.append(f"# TYPE {pn} histogram")
        cum = 0
        for bound, c in zip(h["bounds"], h["counts"]):
            cum += c
            ls = _label_str(labels, extra=("le", f"{bound:g}"))
            out.append(f"{pn}_bucket{ls} {cum}")
        inf = _label_str(labels, extra=("le", "+Inf"))
        out.append(f'{pn}_bucket{inf} {h["count"]}')
        out.append(f"{pn}_sum{base} {h['sum']:g}")
        out.append(f"{pn}_count{base} {h['count']}")
    return "\n".join(out) + ("\n" if out else "")
