"""Sliding time-window metrics: recent quantiles and SLO burn rate.

The all-time histograms (``repro.obs.metrics``) answer "how has this
process behaved since boot"; a latency regression investigation needs
"how is it behaving *now*". ``WindowHistogram`` keeps a **bucket
ring**: the window of the last ``window_s`` seconds is divided into
``n_slots`` time slots, each holding one fixed-bounds bucket-count
array (the same log-spaced bounds as ``Histogram``, so quantile math
is shared). An observation lands in the slot owning the current time;
slots older than the window are lazily zeroed on the next touch, so
the whole structure is O(slots x buckets) memory and O(1) per
observation — no per-sample storage, no background thread.

``quantile``/``count``/``mean`` merge the live slots on demand, which
makes the published ``serve.request_seconds.window.p50``/``p99``
gauges *recent* percentiles (the last ``window_s`` seconds of
traffic), published next to the all-time histogram by
``MappingService.metrics_snapshot`` — computed at scrape time, never
in the request path.

``SLOTracker`` layers a latency SLO on top: a target latency plus a
goal fraction (e.g. 99% of requests under 2 s). Per observation it
counts ok/breach (all-time counters); ``burn_rate()`` is the windowed
breach fraction divided by the error budget ``1 - goal`` — the
standard SRE multiplier where 1.0 means "consuming budget exactly as
fast as allowed", >1 means the SLO will be violated if the window's
behavior persists.

Determinism contract (DESIGN.md Section 12): windows *observe* — no
code path branches on a windowed value, so enabling them changes no
produced number.
"""
from __future__ import annotations

import bisect
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .metrics import DEFAULT_BOUNDS, quantile


class WindowHistogram:
    """Fixed-bucket histogram over a sliding time window (bucket ring).

    ``window_s`` seconds divided into ``n_slots`` slots; each slot
    holds a counts array over ``bounds`` plus its observation count and
    value sum. A slot is reused once its absolute index falls out of
    the window (lazily cleared on write/read), so stale traffic ages
    out within one slot width (``window_s / n_slots`` seconds)."""

    def __init__(self, window_s: float = 60.0, n_slots: int = 12,
                 bounds: Optional[Sequence[float]] = None,
                 clock=time.monotonic):
        assert window_s > 0 and n_slots > 0
        self.window_s = float(window_s)
        self.n_slots = int(n_slots)
        self.slot_s = self.window_s / self.n_slots
        self.bounds: Tuple[float, ...] = \
            tuple(bounds) if bounds is not None else DEFAULT_BOUNDS
        self._clock = clock
        self._lock = threading.Lock()
        n = len(self.bounds) + 1
        self._counts = [[0] * n for _ in range(self.n_slots)]
        self._slot_count = [0] * self.n_slots
        self._slot_sum = [0.0] * self.n_slots
        # absolute slot index each ring position last held (-1 = never)
        self._epoch = [-1] * self.n_slots

    def _slot(self, now: float) -> int:
        """Ring position for ``now``, cleared if it held an old slot.
        Caller holds the lock."""
        idx = int(now // self.slot_s)
        s = idx % self.n_slots
        if self._epoch[s] != idx:
            self._counts[s] = [0] * (len(self.bounds) + 1)
            self._slot_count[s] = 0
            self._slot_sum[s] = 0.0
            self._epoch[s] = idx
        return s

    def observe(self, v: float) -> None:
        """Record one observation at the current time (thread-safe)."""
        now = self._clock()
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            s = self._slot(now)
            self._counts[s][i] += 1
            self._slot_count[s] += 1
            self._slot_sum[s] += v

    def _merged(self) -> Tuple[List[int], int, float]:
        """(counts, count, sum) over the slots still inside the window.
        Caller holds the lock."""
        now = self._clock()
        idx = int(now // self.slot_s)
        live = range(idx - self.n_slots + 1, idx + 1)
        counts = [0] * (len(self.bounds) + 1)
        total, vsum = 0, 0.0
        for s in range(self.n_slots):
            if self._epoch[s] in live and self._slot_count[s]:
                for i, c in enumerate(self._counts[s]):
                    counts[i] += c
                total += self._slot_count[s]
                vsum += self._slot_sum[s]
        return counts, total, vsum

    def snapshot(self) -> Dict:
        """JSON-safe merged view of the live window: ``count``,
        ``sum``, and the merged bucket ``counts`` (same shape as an
        all-time histogram snapshot, plus ``window_s``)."""
        with self._lock:
            counts, total, vsum = self._merged()
        return {"window_s": self.window_s, "bounds": list(self.bounds),
                "counts": counts, "count": total, "sum": vsum}

    def quantile(self, q: float) -> float:
        """Approximate ``q``-quantile over the live window (0.0 when
        the window is empty)."""
        with self._lock:
            counts, _total, _vsum = self._merged()
        return quantile(self.bounds, counts, q)

    def count(self) -> int:
        """Observations inside the live window."""
        with self._lock:
            return self._merged()[1]

    def mean(self) -> float:
        """Mean over the live window (0.0 when empty)."""
        with self._lock:
            _counts, total, vsum = self._merged()
        return vsum / total if total else 0.0


class SLOTracker:
    """Latency SLO accounting: target seconds + goal fraction.

    ``observe(v)`` classifies one request (ok when ``v <= target_s``)
    into all-time counters and a windowed breach ring.
    ``burn_rate()`` = windowed breach fraction / ``(1 - goal)`` — the
    error-budget burn multiplier over the last ``window_s`` seconds
    (0.0 while the window is empty)."""

    def __init__(self, target_s: float, goal: float = 0.99,
                 window_s: float = 60.0, n_slots: int = 12,
                 clock=time.monotonic):
        assert target_s > 0
        assert 0.0 < goal < 1.0, "goal is a fraction like 0.99"
        self.target_s = float(target_s)
        self.goal = float(goal)
        # two-bucket ring: bound at target_s splits ok from breach
        self._ring = WindowHistogram(window_s=window_s, n_slots=n_slots,
                                     bounds=(target_s,), clock=clock)
        self.n_ok = 0
        self.n_breach = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        """Classify one request latency (thread-safe)."""
        self._ring.observe(v)
        with self._lock:
            if v <= self.target_s:
                self.n_ok += 1
            else:
                self.n_breach += 1

    def window_breach_rate(self) -> float:
        """Breach fraction over the live window (0.0 when empty)."""
        snap = self._ring.snapshot()
        if not snap["count"]:
            return 0.0
        return snap["counts"][1] / snap["count"]

    def burn_rate(self) -> float:
        """Windowed breach rate over the error budget ``1 - goal``."""
        return self.window_breach_rate() / (1.0 - self.goal)

    def snapshot(self) -> Dict:
        """JSON-safe state: target/goal, all-time ok/breach counts,
        and the windowed breach/burn rates."""
        with self._lock:
            ok, breach = self.n_ok, self.n_breach
        return {"target_s": self.target_s, "goal": self.goal,
                "ok": ok, "breach": breach,
                "window_breach_rate": self.window_breach_rate(),
                "burn_rate": self.burn_rate()}
