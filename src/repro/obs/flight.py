"""Request flight recorder: a bounded ring of per-request records.

Aggregate counters say *that* serving latency moved; the flight
recorder says *where a given request's milliseconds went*. Every
request through ``MappingService`` leaves one compact record — stage
timings (admit-wait / evaluate / respond, threaded through the staged
``JobQueue``), ``served_from`` provenance, work counters, outcome —
in a fixed-capacity ring buffer (``collections.deque``), so memory is
bounded no matter how long the server runs.

Slow-request retention: records whose ``total_s`` meets
``slow_threshold_s`` keep their **full detail** (the request dict, the
engine cache-hit stats delta of the sweep, the sweep summary) in a
second, separate ring — the interesting requests survive long after
ordinary traffic has rotated them out of the main ring. Both surfaces
are read-only snapshots: ``GET /v1/debug/requests`` lists the recent
ring, ``GET /v1/debug/requests/<key>`` returns the fullest record held
for one request key (prefix match, newest first).

Determinism contract (DESIGN.md Section 12): the recorder *observes* —
nothing reads it on the request path, so enabling/disabling it changes
no produced number (pinned by the serve determinism tests). A
``FlightRecorder(cap=0)`` is a shared no-op.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional

#: record fields every entry carries (detail fields ride on top)
CORE_FIELDS = ("key", "seq", "t_wall", "network", "family", "objective",
               "served_from", "outcome", "status", "admit_wait_s",
               "evaluate_s", "respond_s", "total_s", "evaluated",
               "from_journal", "proposed", "deadline_hit", "slow")


class FlightRecorder:
    """Bounded ring of per-request records with slow-request retention.

    ``cap`` bounds the main ring (0 disables recording entirely);
    ``slow_cap`` bounds the separate full-detail ring;
    ``slow_threshold_s`` is the total-latency bar for full-detail
    retention (``None`` = never). All methods are thread-safe; records
    are plain JSON-safe dicts."""

    def __init__(self, cap: int = 256, slow_threshold_s: float = 1.0,
                 slow_cap: int = 32):
        self.cap = max(0, int(cap))
        self.slow_threshold_s = slow_threshold_s
        self._ring: "deque[Dict]" = deque(maxlen=max(1, self.cap))
        self._slow: "deque[Dict]" = deque(maxlen=max(1, int(slow_cap)))
        self._lock = threading.Lock()
        self._seq = 0

    @property
    def enabled(self) -> bool:
        """False for a ``cap=0`` recorder (every call is a no-op)."""
        return self.cap > 0

    def record(self, rec: Dict, detail: Optional[Dict] = None) -> None:
        """Append one request record. ``rec`` is the compact record
        (stage timings, provenance, counters); ``detail`` holds the
        expensive extras kept only for slow requests. A record at or
        above ``slow_threshold_s`` total latency is flagged ``slow``
        and retained with full detail in the slow ring."""
        if not self.cap:
            return
        slow = (self.slow_threshold_s is not None
                and rec.get("total_s", 0.0) >= self.slow_threshold_s)
        with self._lock:
            self._seq += 1
            entry = dict(rec)
            entry.setdefault("t_wall", time.time())
            entry["seq"] = self._seq
            entry["slow"] = bool(slow)
            self._ring.append(entry)
            if slow:
                full = dict(entry)
                if detail:
                    full.update(detail)
                self._slow.append(full)

    def snapshot(self, limit: Optional[int] = None,
                 slow_only: bool = False) -> List[Dict]:
        """Recent records, newest first (``limit`` caps the list).
        ``slow_only`` reads the full-detail slow ring instead."""
        with self._lock:
            src = self._slow if slow_only else self._ring
            out = [dict(r) for r in reversed(src)]
        return out[:limit] if limit is not None else out

    def get(self, key_prefix: str) -> Optional[Dict]:
        """The fullest record held for a request key (prefix match,
        newest first): the slow ring's full-detail entry when one
        exists, else the compact ring entry; None when unknown."""
        if not key_prefix:
            return None
        with self._lock:
            for src in (self._slow, self._ring):
                for rec in reversed(src):
                    if str(rec.get("key", "")).startswith(key_prefix):
                        return dict(rec)
        return None

    def __len__(self) -> int:
        return len(self._ring)


#: a shared disabled recorder for "no flight recorder" call sites
NULL_RECORDER = FlightRecorder(cap=0)
