"""Stdlib-only telemetry: metrics registry, span tracing, reporting.

The observability layer for the whole reproduction (DESIGN.md
Section 12). Three parts:

* :mod:`repro.obs.metrics` — ``Registry`` of counters / gauges /
  fixed-bucket mergeable histograms, snapshot/merge, Prometheus text
  exposition.
* :mod:`repro.obs.trace` — nestable ``span()`` timing with a JSONL
  ``TraceSink``, counter-based deterministic sampling, and the
  process-global enable/disable switch (off ⇒ shared no-ops).
* :mod:`repro.obs.report` — ``render_report`` turns a snapshot into
  the ``run.py obs-report`` terminal summary.

Typical call-site usage::

    from repro import obs
    obs.inc("dse.evaluated", 3)
    with obs.span("dse.sweep", budget=8):
        ...

All helpers dispatch through the *current* telemetry, so modules
instrumented at import time see a registry enabled later via
``obs.enable(trace_path=..., sample_every=...)``. Hard contract:
telemetry observes, it never steers — results are byte-identical with
telemetry on, off, or sampled (enforced by ``tests/test_obs.py``).
"""
from .metrics import (Counter, Gauge, Histogram, Registry,
                      merge_snapshots, quantile, render_prometheus)
from .report import render_report
from .trace import (NullTelemetry, Telemetry, TraceSink, current, disable,
                    enable, enabled, event, inc, observe, registry,
                    set_gauge, span)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry",
    "merge_snapshots", "quantile", "render_prometheus",
    "render_report",
    "NullTelemetry", "Telemetry", "TraceSink",
    "current", "disable", "enable", "enabled", "event",
    "inc", "observe", "registry", "set_gauge", "span",
]
