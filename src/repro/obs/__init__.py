"""Stdlib-only telemetry: metrics registry, span tracing, reporting.

The observability layer for the whole reproduction (DESIGN.md
Section 12). Three parts:

* :mod:`repro.obs.metrics` — ``Registry`` of counters / gauges /
  fixed-bucket mergeable histograms, snapshot/merge, Prometheus text
  exposition.
* :mod:`repro.obs.trace` — nestable ``span()`` timing with a JSONL
  ``TraceSink``, counter-based deterministic sampling, and the
  process-global enable/disable switch (off ⇒ shared no-ops).
* :mod:`repro.obs.report` — ``render_report`` turns a snapshot into
  the ``run.py obs-report`` terminal summary.
* :mod:`repro.obs.profile` — span-trace analytics (call tree, self/
  total-time attribution, critical path, Chrome trace-event JSON and
  folded-flamegraph export) behind ``run.py obs-profile``.
* :mod:`repro.obs.flight` — ``FlightRecorder``, the bounded ring of
  per-request serving records (stage timings, provenance, slow-request
  full-detail retention) behind ``GET /v1/debug/requests``.
* :mod:`repro.obs.window` — ``WindowHistogram``/``SLOTracker``,
  sliding time-window quantiles and SLO burn rate published as recent
  p50/p99 gauges next to the all-time histograms.

Typical call-site usage::

    from repro import obs
    obs.inc("dse.evaluated", 3)
    with obs.span("dse.sweep", budget=8):
        ...

All helpers dispatch through the *current* telemetry, so modules
instrumented at import time see a registry enabled later via
``obs.enable(trace_path=..., sample_every=...)``. Hard contract:
telemetry observes, it never steers — results are byte-identical with
telemetry on, off, or sampled (enforced by ``tests/test_obs.py``).
"""
from .flight import FlightRecorder
from .metrics import (Counter, Gauge, Histogram, Registry,
                      escape_label_value, merge_snapshots, quantile,
                      render_prometheus)
from .profile import (Trace, attribution, chrome_trace, critical_path,
                      folded_stacks, parse_trace, render_profile)
from .report import render_report
from .trace import (NullTelemetry, Telemetry, TraceSink, current, disable,
                    enable, enabled, event, inc, observe, registry,
                    set_gauge, span)
from .window import SLOTracker, WindowHistogram

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry",
    "escape_label_value", "merge_snapshots", "quantile",
    "render_prometheus",
    "render_report",
    "Trace", "attribution", "chrome_trace", "critical_path",
    "folded_stacks", "parse_trace", "render_profile",
    "FlightRecorder", "SLOTracker", "WindowHistogram",
    "NullTelemetry", "Telemetry", "TraceSink",
    "current", "disable", "enable", "enabled", "event",
    "inc", "observe", "registry", "set_gauge", "span",
]
