"""Human-readable rendering of a metrics snapshot (`obs-report`).

Turns one ``Registry.snapshot()`` dict — possibly the merge of many
worker shards — into the terminal report printed by
``benchmarks/run.py obs-report``: engine memo hit rates, DSE/journal
activity, fleet health, and service latency percentiles. Pure
formatting; all numbers come from the snapshot.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .metrics import quantile


def _rate(hit: float, miss: float) -> str:
    tot = hit + miss
    if tot <= 0:
        return "n/a"
    return f"{hit / tot:.1%} ({int(hit)}/{int(tot)})"


def _hist_line(snap: Dict, name: str) -> Optional[str]:
    h = (snap.get("histograms") or {}).get(name)
    if not h or not h.get("count"):
        return None
    p50 = quantile(h["bounds"], h["counts"], 0.50)
    p99 = quantile(h["bounds"], h["counts"], 0.99)
    mean = h["sum"] / h["count"]
    return (f"n={h['count']} mean={mean * 1e3:.3f}ms "
            f"p50={p50 * 1e3:.3f}ms p99={p99 * 1e3:.3f}ms")


def render_report(snap: Dict) -> str:
    """Render one snapshot as the multi-section text report.

    Sections appear only when their metrics are present, so the same
    renderer serves a bench run (engine only), a dse sweep, a
    distributed fleet merge, and a serving session."""
    c = snap.get("counters") or {}
    g = snap.get("gauges") or {}
    lines: List[str] = []

    def sec(title: str) -> None:
        if lines:
            lines.append("")
        lines.append(title)

    eng = {k: v for k, v in c.items() if k.startswith("engine.")}
    if eng:
        sec("engine")
        for memo in ("tiles", "tail", "proj", "ready", "sepcls", "score",
                     "perf"):
            hit = eng.get(f"engine.{memo}_hit", 0)
            miss = eng.get(f"engine.{memo}_miss", 0)
            if hit or miss:
                lines.append(f"  {memo:<7} hit rate  {_rate(hit, miss)}")
        pool = eng.get("engine.score_pool_hit", 0)
        if pool:
            lines.append(f"  pool-memo hits     {int(pool)}")
        batched = eng.get("engine.batch_scored", 0)
        dense = eng.get("engine.dense_scored", 0)
        guard = eng.get("engine.guard_fallback", 0)
        if batched or dense:
            lines.append(f"  batched scored     {int(batched)}")
            lines.append(f"  dense fallback     {int(dense)} "
                         f"(grid-guard: {int(guard)})")
        ev = eng.get("engine.evictions", 0)
        if ev:
            lines.append(f"  arch evictions     {int(ev)}")
        if "engine.arch_bundles" in g:
            lines.append(f"  live arch bundles  "
                         f"{int(g['engine.arch_bundles'])}")

    if any(k.startswith("dse.") for k in c):
        sec("dse")
        lines.append(f"  proposed           {int(c.get('dse.proposed', 0))}")
        lines.append(f"  evaluated          {int(c.get('dse.evaluated', 0))}")
        lines.append(f"  journal hits       "
                     f"{int(c.get('dse.journal_hits', 0))}")
        h = _hist_line(snap, "dse.eval_seconds")
        if h:
            lines.append(f"  eval latency       {h}")

    if any(k.startswith("journal.") for k in c):
        sec("journal")
        lines.append(f"  records            "
                     f"{int(c.get('journal.records', 0))}")
        lines.append(f"  refresh new rows   "
                     f"{int(c.get('journal.refresh_new', 0))}")
        for nm in ("journal.refresh_seconds", "journal.publish_seconds"):
            h = _hist_line(snap, nm)
            if h:
                lines.append(f"  {nm.split('.')[1]:<18} {h}")

    if any(k.startswith("fleet.") for k in c):
        sec("fleet")
        for key, label in (("fleet.batches", "batches"),
                           ("fleet.evaluated", "evaluated"),
                           ("fleet.claims", "lease claims"),
                           ("fleet.stolen", "lease steals"),
                           ("fleet.expired", "lease expiries"),
                           ("fleet.skipped_done", "skipped done")):
            if key in c:
                lines.append(f"  {label:<18} {int(c[key])}")
        if "fleet.workers" in g:
            lines.append(f"  workers reported   {int(g['fleet.workers'])}")
        h = _hist_line(snap, "fleet.batch_eval_seconds")
        if h:
            lines.append(f"  batch eval         {h}")

    if any(k.startswith("serve.") for k in c):
        sec("serve")
        lines.append(f"  requests           "
                     f"{int(c.get('serve.requests', 0))}")
        for src in ("memo", "journal", "search", "coalesced"):
            k = f"serve.served_from.{src}"
            if k in c:
                lines.append(f"  served from {src:<7}{int(c[k])}")
        lines.append(f"  coalesced          "
                     f"{int(c.get('serve.coalesced', 0))}")
        shed = int(c.get("serve.shed", 0))
        if shed:
            lines.append(f"  shed (429)         {shed}")
        lines.append(f"  sweeps run         "
                     f"{int(c.get('serve.sweeps', 0))}")
        compactions = int(c.get("serve.compactions", 0))
        if compactions:
            lines.append(f"  compactions        {compactions}")
        h = _hist_line(snap, "serve.request_seconds")
        if h:
            lines.append(f"  request latency    {h}")
        wp50 = g.get("serve.request_seconds.window.p50")
        wp99 = g.get("serve.request_seconds.window.p99")
        if wp50 is not None or wp99 is not None:
            n = int(g.get("serve.request_seconds.window.count", 0))
            lines.append(f"  recent latency     n={n} "
                         f"p50={(wp50 or 0) * 1e3:.3f}ms "
                         f"p99={(wp99 or 0) * 1e3:.3f}ms "
                         f"(sliding window)")
        slo_ok = c.get("serve.slo.ok")
        slo_breach = c.get("serve.slo.breach")
        if slo_ok is not None or slo_breach is not None:
            burn = g.get("serve.slo.burn_rate", 0.0)
            lines.append(f"  slo                ok={int(slo_ok or 0)} "
                         f"breach={int(slo_breach or 0)} "
                         f"burn_rate={burn:.2f}")
        if "serve.queue.depth" in g:
            lines.append(f"  queue depth (last) "
                         f"{int(g['serve.queue.depth'])}")

    flight = snap.get("flight") or []
    if flight:
        sec("flight recorder (most recent first)")
        lines.append(f"  {'key':<14} {'from':<9} {'outcome':<7} "
                     f"{'admit_ms':>9} {'eval_ms':>9} {'resp_ms':>9} "
                     f"{'total_ms':>9} {'eval':>5}")
        for rec in flight[:10]:
            lines.append(
                f"  {str(rec.get('key', ''))[:12]:<14} "
                f"{str(rec.get('served_from', ''))[:8]:<9} "
                f"{str(rec.get('outcome', ''))[:7]:<7} "
                f"{rec.get('admit_wait_s', 0) * 1e3:>9.2f} "
                f"{rec.get('evaluate_s', 0) * 1e3:>9.2f} "
                f"{rec.get('respond_s', 0) * 1e3:>9.2f} "
                f"{rec.get('total_s', 0) * 1e3:>9.2f} "
                f"{int(rec.get('evaluated', 0)):>5}"
                + (" SLOW" if rec.get("slow") else ""))
        n_slow = sum(1 for r in flight if r.get("slow"))
        if n_slow:
            lines.append(f"  ({n_slow} slow request(s) retained with "
                         "full detail — GET /v1/debug/requests/<key>)")

    if not lines:
        return "(no metrics recorded)\n"
    return "\n".join(lines) + "\n"
