"""Pure-jnp oracle for the overlap-fused SwiGLU MLP."""
import jax
import jax.numpy as jnp


def fused_mlp_ref(x, w1, w3, w2):
    h = jax.nn.silu(jnp.dot(x, w1, preferred_element_type=jnp.float32))
    h = h * jnp.dot(x, w3, preferred_element_type=jnp.float32)
    y = jnp.dot(h.astype(x.dtype), w2,
                preferred_element_type=jnp.float32)
    return y.astype(x.dtype)
