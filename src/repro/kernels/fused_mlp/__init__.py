from .fused_mlp import fused_mlp
from .ops import fused_mlp_op, hbm_bytes_fused, hbm_bytes_unfused
from .ref import fused_mlp_ref
