"""jit'd public wrapper for the overlap-fused MLP kernel."""
import functools

import jax

from .fused_mlp import fused_mlp
from .ref import fused_mlp_ref


@functools.partial(jax.jit, static_argnames=("tm", "tf", "interpret"))
def fused_mlp_op(x, w1, w3, w2, tm=128, tf=512, interpret=False):
    return fused_mlp(x, w1, w3, w2, tm=tm, tf=tf, interpret=interpret)


def hbm_bytes_fused(m, k, f, itemsize=2):
    """HBM traffic model: x re-read per F tile is amortized by tiling; w
    read once; y written once."""
    n_ftiles = max(f // 512, 1)
    return (m * k * n_ftiles + 3 * k * f + m * k) * itemsize


def hbm_bytes_unfused(m, k, f, itemsize=2):
    """Unfused: x read twice, h1/h3 written+read, h written+read, w once,
    y written."""
    return (2 * m * k + 3 * k * f + 4 * m * f + m * f + m * k) * itemsize
