"""Overlap-fused SwiGLU MLP kernel (Pallas TPU).

This is the paper's computational-overlap idea mapped onto the TPU memory
hierarchy (DESIGN.md Section 3, level 1): the consumer matmul (@W2)
consumes each d_ff block of the producer (x@W1, x@W3) AS SOON as it is
produced, in VMEM — the [M, d_ff] intermediate never round-trips to HBM:

    y = sum_j act(x @ W1[:, j]) * (x @ W3[:, j]) @ W2[j, :]

Grid (M_tiles, F_tiles), F minor: the fp32 accumulator for one M tile
lives in a VMEM scratch across the F sweep (the PIM "bank time step" maps
to one (m, j) grid step; "ready-time" = the producer block's grid step,
which immediately precedes its consumption).

HBM traffic: x read F_tiles times, W1/W3/W2 read once, y written once —
vs the unfused 2x(d_ff intermediate) + weights. With tm=256, tf=512 on
granite_8b shapes this removes ~45% of MLP HBM bytes (see
benchmarks/bench_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_ref, *,
            n_ftiles: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    # producer block: h_j = silu(x @ W1_j) * (x @ W3_j)   (in VMEM)
    h1 = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h3 = jnp.dot(x, w3_ref[...], preferred_element_type=jnp.float32)
    h = (h1 * jax.lax.logistic(h1)) * h3
    # consumer: overlapped accumulation into the output tile
    acc_ref[...] += jnp.dot(h.astype(x.dtype), w2_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_ftiles - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_mlp(x, w1, w3, w2, *, tm: int = 128, tf: int = 512,
              interpret: bool = False):
    """x [M, K]; w1/w3 [K, F]; w2 [F, K] -> [M, K]."""
    m, k = x.shape
    f = w1.shape[1]
    tm = min(tm, m)
    tf = min(tf, f)
    assert m % tm == 0 and f % tf == 0, (m, tm, f, tf)
    grid = (m // tm, f // tf)
    return pl.pallas_call(
        functools.partial(_kernel, n_ftiles=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tf), lambda i, j: (0, j)),
            pl.BlockSpec((k, tf), lambda i, j: (0, j)),
            pl.BlockSpec((tf, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        scratch_shapes=[pltpu.VMEM((tm, k), jnp.float32)],
        interpret=interpret,
    )(x, w1, w3, w2)
