"""Pallas TPU kernels for the compute hot-spots (validated in
interpret mode against pure-jnp oracles; see tests/test_kernels.py)."""
