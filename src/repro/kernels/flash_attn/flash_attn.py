"""FlashAttention (Pallas TPU), causal + GQA.

The dry-run roofline showed the einsum-based online-softmax attention is
memory-bound at 32k: the [Sq, chunk] score tensors round-trip to HBM
between the two dots. This kernel keeps scores, running max and
normalizer in VMEM across the KV sweep (grid minor axis), writing only
the [Sq, hd] output — the paper's producer->consumer overlap applied to
the QK^T -> softmax -> AV chain.

Layouts: q [BH, Sq, hd]; k/v [BKV, Skv, hd]; GQA resolved in the k/v
BlockSpec index maps (no KV repetition in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            tq: int, tk: int, n_ktiles: int, causal: bool, scale: float,
            q_offset: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: whole block above the diagonal -> skip all compute
    # (queries sit at the LAST sq positions of the kv sequence)
    run = True
    if causal:
        run = j * tk <= q_offset + (i + 1) * tq - 1

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale        # [tq, hd]
        k = k_ref[0].astype(jnp.float32)                # [tk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # [tq, tk]
        if causal:
            rows = q_offset + i * tq + jax.lax.broadcasted_iota(
                jnp.int32, (tq, tk), 0)
            cols = j * tk + jax.lax.broadcasted_iota(
                jnp.int32, (tq, tk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                             # [tq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                  # [tq, 1]
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)         # [tq, hd]
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(j == n_ktiles - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, tq: int = 256,
                    tk: int = 256, interpret: bool = False):
    """q [BH, Sq, hd]; k/v [BKV, Skv, hd]; BH = BKV * G. -> [BH, Sq, hd]"""
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    g = bh // bkv
    tq, tk = min(tq, sq), min(tk, skv)
    assert sq % tq == 0 and skv % tk == 0
    grid = (bh, sq // tq, skv // tk)
    scale = 1.0 / (hd ** 0.5)
    return pl.pallas_call(
        functools.partial(_kernel, tq=tq, tk=tk, n_ktiles=grid[2],
                          causal=causal, scale=scale,
                          q_offset=skv - sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, hd), lambda b, i, j: (b // g, j, 0)),
            pl.BlockSpec((1, tk, hd), lambda b, i, j: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, hd), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
