"""Pure-jnp oracle: exact softmax attention (causal, GQA via repeat)."""
import jax.numpy as jnp
import jax


def attention_ref(q, k, v, causal=True):
    """q [BH, Sq, hd]; k/v [BKV, Skv, hd]."""
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    g = bh // bkv
    kk = jnp.repeat(k, g, axis=0)
    vv = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)
