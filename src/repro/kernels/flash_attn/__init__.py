from .flash_attn import flash_attention
from .ops import flash_attention_op, hbm_bytes_flash, hbm_bytes_unfused
from .ref import attention_ref
