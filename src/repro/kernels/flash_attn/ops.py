"""jit'd public wrapper for the flash attention kernel."""
import functools

import jax

from .flash_attn import flash_attention


@functools.partial(jax.jit,
                   static_argnames=("causal", "tq", "tk", "interpret"))
def flash_attention_op(q, k, v, causal=True, tq=256, tk=256,
                       interpret=False):
    return flash_attention(q, k, v, causal=causal, tq=tq, tk=tk,
                           interpret=interpret)


def hbm_bytes_flash(bh, sq, skv, hd, itemsize=2):
    """q,k,v read once (k/v per q-tile sweep amortized by grid), o written."""
    return (bh * sq * hd * 2 + bh * skv * hd * 2 * (sq // 256)) * itemsize


def hbm_bytes_unfused(bh, sq, skv, hd, itemsize=2):
    """scores + softmax round-trips dominate."""
    return (bh * sq * hd * 3 + bh * skv * hd * 2
            + 4 * bh * sq * skv  # scores written+read, f32-ish
            ) * itemsize
