"""jit'd public wrapper for the SSD chunk-scan kernel."""
import functools

import jax

from .ssd_scan import ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_op(x, dt, a, bm, cm, chunk=128, interpret=False):
    return ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=interpret)
