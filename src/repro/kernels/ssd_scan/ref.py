"""Oracle: naive sequential SSD recurrence (per time step, pure jnp)."""
import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, bm, cm):
    """x [BH, S, P]; dt [BH, S, 1]; a [BH, 1, 1]; bm/cm [BH, S, N].

    h_t = exp(dt_t * a) h_{t-1} + dt_t * B_t (x) x_t ; y_t = C_t . h_t
    """
    bh, s, p = x.shape
    n = bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp            # [P],[1],[N],[N] per bh batch
        da = jnp.exp(dtt * a[:, 0, 0])   # [BH]
        h = h * da[:, None, None] + jnp.einsum(
            "bn,b,bp->bnp", bt, dtt, xt)
        y = jnp.einsum("bn,bnp->bp", ct, h)
        return h, y

    h0 = jnp.zeros((bh, n, p), jnp.float32)
    xs = (x.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2)[..., 0].astype(jnp.float32),
          bm.transpose(1, 0, 2).astype(jnp.float32),
          cm.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype)
