from .ops import ssd_scan_op
from .ref import ssd_ref
from .ssd_scan import ssd_scan
