"""Mamba-2 SSD chunk scan (Pallas TPU).

One grid step processes one (batch*head, chunk) cell: the quadratic
intra-chunk term plus the contribution of the running inter-chunk state,
which is carried ACROSS grid steps in a VMEM scratch (TPU grids execute
minor-axis-sequentially, so the chunk axis acts as the recurrence loop —
the same producer->consumer overlap structure as the paper's bank
time-steps).

Layouts: x [BH, S, P]; dt [BH, S, 1]; A [BH, 1, 1] (per-head scalar,
pre-gathered); Bm/Cm [BH, S, N] (group-expanded via index maps upstream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
            chunk: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # [L, P]
    dt = dt_ref[0].astype(jnp.float32)        # [L, 1]
    a = a_ref[0, 0, 0].astype(jnp.float32)    # scalar (negative)
    bm = b_ref[0].astype(jnp.float32)         # [L, N]
    cm = c_ref[0].astype(jnp.float32)         # [L, N]

    da = dt * a                               # [L, 1]
    cum = jnp.cumsum(da, axis=0)              # [L, 1]
    # intra-chunk: M[i,j] = (C_i . B_j) exp(cum_i - cum_j) dt_j, i >= j
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    seg = cum - cum[:, 0][None, :]            # [L, L] (cum_i - cum_j)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(rows >= cols, jnp.exp(seg), 0.0)
    m = scores * decay * dt[:, 0][None, :]
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (C exp(cum)) @ state_prev ; state update
    state = state_ref[...]                    # [N, P]
    y += jax.lax.dot_general(cm * jnp.exp(cum), state,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dec_state = jnp.exp(cum[-1, 0] - cum[:, 0])[:, None]   # [L, 1]
    sc = jax.lax.dot_general(bm * (dec_state * dt), x,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    state_ref[...] = state * jnp.exp(cum[-1, 0]) + sc
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, a, bm, cm, *, chunk: int = 128,
             interpret: bool = False):
    """x [BH, S, P]; dt [BH, S, 1]; a [BH, 1, 1]; bm/cm [BH, S, N]."""
    bh, s, p = x.shape
    n = bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    grid = (bh, s // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bm, cm)
