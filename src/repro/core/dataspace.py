"""Fine-grained data space generation (paper Section IV-E/F).

A *data space* is the hyper-rectangle of tensor coordinates processed by one
analysis-level instance (bank) in one time step. This module produces the
full (bank, step) -> rectangle map two ways:

* ``generate_exhaustive`` — recursive enumeration of the loop nest, the way
  Timeloop/OverlaPIM materialize data spaces (paper: "recursive function
  calls ... around 600 seconds"). Pure-Python, O(n) spaces with large
  constants. Kept as the oracle.
* ``generate_analytical`` — the paper's lightweight algorithm: every loop
  level contributes ``idx * block_size`` to the offset, where the temporal
  index increment is the closed-form stride of Eq (1)/(2). Vectorized with
  numpy ("less than 60 seconds" in the paper; orders of magnitude faster
  here too — measured in benchmarks/bench_dataspace.py).

Both return identical ``DataSpaces`` (property-checked in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from .mapping import Mapping
from .workload import DIMS, OUTPUT_DIMS, REDUCTION_DIMS


@dataclasses.dataclass
class DataSpaces:
    """Rectangles per (bank, step): ``offsets[d][b, t]`` is the lower corner
    of dim ``d``; extents are mapping-constant (``extent[d]``)."""

    mapping: Mapping
    offsets: Dict[str, np.ndarray]  # dim -> (n_banks, n_steps) int64
    extent: Dict[str, int]

    @property
    def n_banks(self) -> int:
        return self.mapping.n_banks

    @property
    def n_steps(self) -> int:
        return self.mapping.n_steps

    @property
    def n_spaces(self) -> int:
        return self.n_banks * self.n_steps

    def rect(self, b: int, t: int, dims=OUTPUT_DIMS):
        """[(lo, hi_exclusive)] per dim for one space."""
        return {d: (int(self.offsets[d][b, t]),
                    int(self.offsets[d][b, t]) + self.extent[d])
                for d in dims}

    def equals(self, other: "DataSpaces") -> bool:
        if self.extent != other.extent:
            return False
        return all(np.array_equal(self.offsets[d], other.offsets[d])
                   for d in DIMS)


def generate_analytical(mapping: Mapping,
                        dims=DIMS) -> DataSpaces:
    """Closed-form generation, O(n_spaces) vectorized (paper Eq (1)/(2))."""
    nb, nt = mapping.n_banks, mapping.n_steps
    steps = np.arange(nt, dtype=np.int64)
    banks = np.arange(nb, dtype=np.int64)
    offsets = {d: np.zeros((nb, nt), dtype=np.int64) for d in dims}
    for lp, blk, tstride, bstride in mapping.rect_loops:
        if lp.dim not in offsets:
            continue
        if lp.spatial:
            idx = (banks // bstride) % lp.size            # (nb,)
            offsets[lp.dim] += (idx * blk)[:, None]
        else:
            idx = (steps // tstride) % lp.size            # (nt,)
            offsets[lp.dim] += (idx * blk)[None, :]
    extent = {d: mapping.tile_extent[d] for d in dims}
    return DataSpaces(mapping=mapping, offsets=offsets, extent=extent)


def rect_bounds(mapping: Mapping, dims=DIMS):
    """Lower / upper (exclusive) corners of every (bank, step) rectangle:
    ``(lo, hi)`` dicts of (n_banks, n_steps) arrays. This is the
    consumer-tile view shared by overlap analysis and the batched engine
    (which flattens and stacks these across candidate mappings)."""
    ds = generate_analytical(mapping, dims)
    lo = {d: ds.offsets[d] for d in dims}
    hi = {d: ds.offsets[d] + ds.extent[d] for d in dims}
    return lo, hi


def rect_bounds_stacked(mappings, dims=DIMS):
    """``rect_bounds`` for K candidate mappings, stacked along a leading
    candidate axis: per dim one 1-D concatenation of the flattened
    ``(n_banks * n_steps)`` rect corners of every candidate, plus the
    slice offsets delimiting each candidate's segment. The batched engine
    runs coordinate maps and digit scans once over the concatenation
    instead of per candidate — elementwise ops on the stack are
    bit-identical to the per-candidate grids."""
    sizes = [m.n_banks * m.n_steps for m in mappings]
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
    total = int(offsets[-1])
    lo = {d: np.empty(total, dtype=np.int64) for d in dims}
    hi = {d: np.empty(total, dtype=np.int64) for d in dims}
    for k, m in enumerate(mappings):
        l, h = rect_bounds(m, dims)
        o0, o1 = offsets[k], offsets[k + 1]
        for d in dims:
            lo[d][o0:o1] = l[d].reshape(-1)
            hi[d][o0:o1] = h[d].reshape(-1)
    return lo, hi, offsets


def rect_bounds_separable_stacked(mappings, dims=DIMS):
    """``rect_bounds_separable`` for K candidate mappings, stacked: per dim
    the bank parts of all candidates concatenated (offsets ``boff``) and
    the step parts concatenated (offsets ``toff``), plus each candidate's
    extent dict. One allocation per dim serves the whole batch and the
    engine's class/interval dedup runs pooled over the concatenation."""
    nbs = [m.n_banks for m in mappings]
    nts = [m.n_steps for m in mappings]
    boff = np.concatenate([[0], np.cumsum(nbs)]).astype(np.int64)
    toff = np.concatenate([[0], np.cumsum(nts)]).astype(np.int64)
    bank_part = {d: np.zeros(int(boff[-1]), dtype=np.int64) for d in dims}
    step_part = {d: np.zeros(int(toff[-1]), dtype=np.int64) for d in dims}
    aranges: Dict[int, np.ndarray] = {}
    for k, m in enumerate(mappings):
        nb, nt = nbs[k], nts[k]
        steps = aranges.get(nt)
        if steps is None:
            steps = aranges[nt] = np.arange(nt, dtype=np.int64)
        banks = aranges.get(nb)
        if banks is None:
            banks = aranges[nb] = np.arange(nb, dtype=np.int64)
        b0, t0 = int(boff[k]), int(toff[k])
        for lp, blk, tstride, bstride in m.rect_loops:
            if lp.dim not in bank_part:
                continue
            if lp.spatial:
                bank_part[lp.dim][b0:b0 + nb] += (
                    (banks // bstride) % lp.size) * blk
            else:
                step_part[lp.dim][t0:t0 + nt] += (
                    (steps // tstride) % lp.size) * blk
    extents = [{d: m.tile_extent[d] for d in dims} for m in mappings]
    return bank_part, step_part, extents, boff, toff


def rect_bounds_separable(mapping: Mapping, dims=DIMS):
    """Factored form of ``rect_bounds``: per dim ``d`` the lower corner is
    ``bank_part[d][b] + step_part[d][t]`` (spatial loops index only the
    bank axis, temporal loops only the step axis — Eq (1)/(2) is a sum of
    independent digit contributions). O(n_banks + n_steps) instead of
    O(n_banks * n_steps); the batched engine dedups interval combos from
    these parts instead of materializing the full grid. ``extent`` is the
    mapping-constant rectangle size per dim."""
    nb, nt = mapping.n_banks, mapping.n_steps
    steps = np.arange(nt, dtype=np.int64)
    banks = np.arange(nb, dtype=np.int64)
    bank_part = {d: np.zeros(nb, dtype=np.int64) for d in dims}
    step_part = {d: np.zeros(nt, dtype=np.int64) for d in dims}
    for lp, blk, tstride, bstride in mapping.rect_loops:
        if lp.dim not in bank_part:
            continue
        if lp.spatial:
            bank_part[lp.dim] += ((banks // bstride) % lp.size) * blk
        else:
            step_part[lp.dim] += ((steps // tstride) % lp.size) * blk
    extent = {d: mapping.tile_extent[d] for d in dims}
    return bank_part, step_part, extent


def generate_exhaustive(mapping: Mapping, dims=DIMS) -> DataSpaces:
    """Recursive enumeration of the nest (Timeloop-style reference)."""
    nb, nt = mapping.n_banks, mapping.n_steps
    offsets = {d: np.zeros((nb, nt), dtype=np.int64) for d in dims}
    rect_loops = mapping.rect_loops
    n_loops = len(rect_loops)
    cur_off = {d: 0 for d in dims}

    def rec(i: int, bank: int, step: int) -> None:
        if i == n_loops:
            for d in dims:
                offsets[d][bank, step] = cur_off[d]
            return
        lp, blk, tstride, bstride = rect_loops[i]
        for k in range(lp.size):
            if lp.dim in cur_off:
                prev = cur_off[lp.dim]
                cur_off[lp.dim] = prev + k * blk
            if lp.spatial:
                rec(i + 1, bank + k * bstride, step)
            else:
                rec(i + 1, bank, step + k * tstride)
            if lp.dim in cur_off:
                cur_off[lp.dim] = prev
    rec(0, 0, 0)
    extent = {d: mapping.tile_extent[d] for d in dims}
    return DataSpaces(mapping=mapping, offsets=offsets, extent=extent)


# ---------------------------------------------------------------------------
# Point location (paper Eq (5)/(6)): which (bank, step) produces a coord.
# ---------------------------------------------------------------------------

def locate_finish(mapping: Mapping, coords: Dict[str, np.ndarray]):
    """Finish (bank, step) of output coordinates, vectorized.

    ``coords`` maps each of K/P/Q to an equal-shape int array. Returns
    ``(bank, step)`` arrays. Reduction loops (C/R/S) are taken at their last
    iteration — an output element is complete only once its whole reduction
    has run (Section IV-H: "the total sizes will be added to the temporal
    index for the finalized time step").
    """
    shape = np.broadcast(*coords.values()).shape
    step = np.zeros(shape, dtype=np.int64)
    bank = np.zeros(shape, dtype=np.int64)
    for lp, blk, tstride, bstride in mapping.rect_loops:
        if lp.dim in coords:
            idx = (coords[lp.dim] // blk) % lp.size
        elif lp.dim in REDUCTION_DIMS:
            idx = lp.size - 1
        else:  # untracked dim (e.g. N) — production order irrelevant
            idx = lp.size - 1
        if lp.spatial:
            bank = bank + idx * bstride
        else:
            step = step + idx * tstride
    return bank, step


def locate_finish_exhaustive(spaces: DataSpaces,
                             lo: Dict[str, int],
                             hi: Dict[str, int]):
    """OverlaPIM-style exhaustive location: scan *all* producer data spaces,
    keep the latest step whose rectangle intersects [lo, hi) (output dims
    only). O(n_spaces) per query. Returns (bank, step) or (-1, -1)."""
    best_t, best_b = -1, -1
    offs = spaces.offsets
    ext = spaces.extent
    for b in range(spaces.n_banks):
        for t in range(spaces.n_steps):
            inter = True
            for d in OUTPUT_DIMS:
                o = int(offs[d][b, t])
                if not (o < hi[d] and o + ext[d] > lo[d]):
                    inter = False
                    break
            if inter and t > best_t:
                best_t, best_b = t, b
    return best_b, best_t
