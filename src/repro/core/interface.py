"""DNN interface (paper Section IV-B): whole-network descriptions in.

Takes a network name (or explicit layer list), emits the per-layer
workloads plus the dependency edges feeding overlap analysis, and runs the
whole-network optimization. Conv chains use identity coordinate maps; the
BERT encoder (Section VI) wires the attention dataflow, including the
sibling edges where QK consumes K-proj outputs as its stationary operand
and AV consumes V-proj outputs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from .arch import ArchSpec, dram_pim
from .overlap import (Edge, HeadFoldMap, HeadUnfoldMap, IdentityMap,
                      WeightMap)
from .search import NetworkResult, SearchConfig, optimize_network
from .workload import NETWORKS, LayerSpec, bert_encoder, get_network


@dataclasses.dataclass
class NetworkDesc:
    name: str
    layers: List[LayerSpec]
    edges: List[List[Edge]]     # per layer, producers it depends on


def _pool_between(prod: LayerSpec, cons: LayerSpec) -> int:
    """Infer an elementwise pooling factor between two conv layers from
    the spatial-shape mismatch (VGG pools, ResNet stem maxpool)."""
    need_h = (cons.P - 1) * cons.stride + cons.R - 2 * cons.pad
    if need_h <= 0 or prod.P % need_h:
        return 1
    return max(1, prod.P // need_h)


def chain_edges(layers: Sequence[LayerSpec]) -> List[List[Edge]]:
    """Sequential conv/FC chain: layer i consumes layer i-1 (pooling
    between blocks inferred from shapes)."""
    edges: List[List[Edge]] = [[]]
    for i in range(1, len(layers)):
        pool = _pool_between(layers[i - 1], layers[i])
        edges.append([Edge(i - 1, IdentityMap(pool=pool))])
    return edges


def _edge(layers, j, i) -> Edge:
    return Edge(j, IdentityMap(pool=_pool_between(layers[j], layers[i])))


def resnet18_edges(layers: Sequence[LayerSpec]) -> List[List[Edge]]:
    """Residual wiring: downsample convs consume the stage input; the
    block after an add consumes both the main path and the skip path
    (paper Section IV-J treats skip layers as latency-neutral, but their
    outputs still gate the next block's inputs)."""
    name_idx = {l.name: j for j, l in enumerate(layers)}
    edges: List[List[Edge]] = []
    for i, l in enumerate(layers):
        n = l.name
        if n == "conv1":
            edges.append([])
        elif n.endswith("b0c1") or n.endswith("b0ds"):
            # stage entry: consumes previous stage's block output
            prev = i - 1 if n.endswith("b0c1") else i - 3
            while layers[prev].name.endswith("ds"):
                prev -= 1
            edges.append([_edge(layers, prev, i)])
        elif n.endswith("b1c1"):
            # after the add: main (b0c2) + skip (b0ds if present)
            es = [_edge(layers, name_idx[n[:-4] + "b0c2"], i)]
            ds = n[:-4] + "b0ds"
            if ds in name_idx:
                es.append(_edge(layers, name_idx[ds], i))
            edges.append(es)
        else:  # c2-of-block: consumes its c1
            edges.append([_edge(layers, i - 1, i)])
    return edges


def describe(name: str, **kw) -> NetworkDesc:
    """Network name (or zoo scenario string) -> ``NetworkDesc``.

    Core names (``resnet18``/``vgg16``/``resnet50``/``bert_encoder``)
    resolve here; anything else is handed to the LLM lowering layer
    (``repro.workloads``), whose scenario grammar is
    ``<arch>[:phase][@length][xblocks]``. Keyword arguments are only
    legal where something consumes them (bert shapes, scenario shapes) —
    unconsumed kwargs raise instead of silently returning the default
    network."""
    if name == "bert_encoder":
        return describe_bert(**kw)
    if name in NETWORKS:
        if kw:
            raise TypeError(
                f"describe({name!r}) takes no keyword arguments (got "
                f"{sorted(kw)}); only bert_encoder and zoo scenarios "
                "are parameterizable")
        layers = get_network(name)
        if name == "resnet18":
            return NetworkDesc(name=name, layers=layers,
                               edges=resnet18_edges(layers))
        return NetworkDesc(name=name, layers=layers,
                           edges=chain_edges(layers))
    # not a core network: the LLM workload lowering layer (lazy import —
    # repro.workloads pulls in the model zoo, which imports jax)
    from ..workloads import describe_scenario
    return describe_scenario(name, **kw)


def known_network(name: str) -> bool:
    """Cheap existence check for request validation: True iff ``name``
    is a core network or parses as a zoo scenario. No layers are built
    (an unknown name must be rejectable without paying a lowering)."""
    if name == "bert_encoder" or name in NETWORKS:
        return True
    try:
        from ..workloads import is_scenario_name
    except ImportError:          # zoo deps unavailable in this build
        return False
    return is_scenario_name(name)


def describe_bert(seq: int = 512, d_model: int = 768, heads: int = 12,
                  d_ff: int = 3072) -> NetworkDesc:
    layers = bert_encoder(seq, d_model, heads, d_ff)
    hd = d_model // heads
    # layer order: q(0) k(1) v(2) qk(3) av(4) out(5) ffn1(6) ffn2(7)
    edges: List[List[Edge]] = [
        [],                                    # q_proj  <- embeddings
        [],                                    # k_proj  <- embeddings
        [],                                    # v_proj  <- embeddings
        [Edge(0, HeadFoldMap(seq, hd)),        # qk: input = Q
         Edge(1, WeightMap(seq, hd, "qk_weight"))],   # stationary = K^T
        [Edge(3, IdentityMap()),               # av: input = scores
         Edge(2, WeightMap(seq, hd, "av_weight"))],   # stationary = V
        [Edge(4, HeadUnfoldMap(seq, hd))],     # out_proj
        [Edge(5, IdentityMap())],              # ffn1
        [Edge(6, IdentityMap())],              # ffn2
    ]
    return NetworkDesc(name="bert_encoder", layers=layers, edges=edges)


def optimize(name: str, arch: Optional[ArchSpec] = None,
             cfg: Optional[SearchConfig] = None) -> NetworkResult:
    """One-call whole-network optimization (the Fig 5 flow)."""
    desc = describe(name)
    return optimize_network(desc.layers, desc.edges,
                            arch or dram_pim(), cfg or SearchConfig())
