"""Overlap analysis between consecutive layers (paper Sections IV-G/H).

For every consumer (bank, step) data space we find the *ready time*: the
moment the preceding layer has finished producing every input element the
space needs. Two implementations:

* ``ready_steps_exhaustive`` — OverlaPIM's O(N*M) traversal comparing all
  producer/consumer data spaces (the baseline the paper speeds up).
* ``ready_steps_analytical`` — the paper's closed-form algorithm
  (Eq (3)-(6)): map the consumer space's input rectangle into producer
  output coordinates, then locate the producer (bank, step) containing the
  rectangle's max corner via mixed-radix division; reduction loops are
  taken at their last iteration. Because the bank-step index is separable
  and monotone per tile index, the max corner's space IS the latest
  intersecting space (property-verified against the exhaustive oracle).

Scheduling given ready times uses the recurrence
``end[t] = max(end[t-1], ready[t]) + L`` whose closed form
``end[t] = L*(t+1) + running_max(ready[s] - s*L)`` is vectorized.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .dataspace import generate_analytical, locate_finish, rect_bounds
from .mapping import Mapping
from .workload import LayerSpec, OUTPUT_DIMS

Rect = Dict[str, np.ndarray]  # dim -> lo / hi arrays


# ---------------------------------------------------------------------------
# Coordinate maps: consumer input rectangle -> producer output bounding box.
# ---------------------------------------------------------------------------

class CoordMap:
    """Maps a consumer tile (lo/hi per dim, in the consumer's 7D coords) to
    a bounding rectangle in the producer's output space [K, P, Q], plus a
    mask of spaces that are ready at t=0 (e.g. fully inside padding).
    Coordinate conventions are specified in DESIGN.md Section 5.2."""

    def to_producer(self, producer: LayerSpec, consumer: LayerSpec,
                    lo: Rect, hi: Rect) -> Tuple[Rect, Rect, np.ndarray]:
        raise NotImplementedError

    def key(self) -> Tuple:
        """Hashable content identity (cache key for the batched engine).
        ``to_producer`` must be a pure function of this key and its
        arguments."""
        raise NotImplementedError


class IdentityMap(CoordMap):
    """Conv/FC chain: consumer input channel -> producer K, input pixel
    (h, w) -> producer (P, Q) through stride/pad/filter-offset. ``pool``
    models an elementwise pooling layer between the two convs (VGG,
    ResNet stem): input pixel h reads producer rows
    [pool*h, pool*h + pool)."""

    def __init__(self, pool: int = 1):
        self.pool = pool

    def key(self):
        return ("identity", self.pool)

    def to_producer(self, producer, consumer, lo, hi):
        st, pad, pool = consumer.stride, consumer.pad, self.pool
        h_lo = (lo["P"] * st - pad + lo["R"]) * pool
        h_hi = ((hi["P"] - 1) * st - pad + (hi["R"] - 1)) * pool + pool - 1
        w_lo = (lo["Q"] * st - pad + lo["S"]) * pool
        w_hi = ((hi["Q"] - 1) * st - pad + (hi["S"] - 1)) * pool + pool - 1
        ready0 = ((h_hi < 0) | (w_hi < 0)
                  | (h_lo >= producer.P) | (w_lo >= producer.Q))
        plo = {"K": lo["C"], "P": np.maximum(h_lo, 0),
               "Q": np.maximum(w_lo, 0)}
        phi = {"K": hi["C"],
               "P": np.minimum(h_hi, producer.P - 1) + 1,
               "Q": np.minimum(w_hi, producer.Q - 1) + 1}
        return plo, phi, ready0


class HeadFoldMap(CoordMap):
    """seq x (heads*hd) producer -> heads-folded consumer (rows h*seq+m).

    Consumer input coord (c, row) needs producer output (P=row%seq,
    K=(row//seq)*hd + c). Bounding box is conservative when a tile spans a
    head boundary (documented in DESIGN.md Section 5.3)."""

    def __init__(self, seq: int, hd: int):
        self.seq, self.hd = seq, hd

    def key(self):
        return ("headfold", self.seq, self.hd)

    def to_producer(self, producer, consumer, lo, hi):
        seq, hd = self.seq, self.hd
        r_lo, r_hi = lo["P"], hi["P"] - 1
        h_lo, h_hi = r_lo // seq, r_hi // seq
        spans = h_hi > h_lo
        m_lo = np.where(spans, 0, r_lo % seq)
        m_hi = np.where(spans, seq - 1, r_hi % seq)
        k_lo = h_lo * hd + lo["C"]
        k_hi = h_hi * hd + hi["C"] - 1
        ready0 = np.zeros(r_lo.shape, dtype=bool)
        return ({"K": k_lo, "P": m_lo, "Q": np.zeros_like(r_lo)},
                {"K": k_hi + 1, "P": m_hi + 1, "Q": np.ones_like(r_lo)},
                ready0)


class HeadUnfoldMap(CoordMap):
    """heads-folded producer (rows h*seq+m, K=hd cols) -> seq x (heads*hd)
    consumer. Consumer input coord (c, m): h=c//hd, j=c%hd -> producer
    (P=h*seq+m, K=j)."""

    def __init__(self, seq: int, hd: int):
        self.seq, self.hd = seq, hd

    def key(self):
        return ("headunfold", self.seq, self.hd)

    def to_producer(self, producer, consumer, lo, hi):
        seq, hd = self.seq, self.hd
        c_lo, c_hi = lo["C"], hi["C"] - 1
        h_lo, h_hi = c_lo // hd, c_hi // hd
        spans = h_hi > h_lo
        j_lo = np.where(spans, 0, c_lo % hd)
        j_hi = np.where(spans, hd - 1, c_hi % hd)
        p_lo = h_lo * seq + lo["P"]
        p_hi = h_hi * seq + hi["P"] - 1
        ready0 = np.zeros(c_lo.shape, dtype=bool)
        return ({"K": j_lo, "P": p_lo, "Q": np.zeros_like(c_lo)},
                {"K": j_hi + 1, "P": p_hi + 1, "Q": np.ones_like(c_lo)},
                ready0)


class WeightMap(CoordMap):
    """Consumer *weight* tile -> producer output. Used for attention edges
    where a matmul's stationary operand (K^T in QK, V in AV) is produced by
    a sibling layer. ``kc_to`` maps (k range, c range, head range from the
    row block) to producer (K, P) bounds. ``group`` models GQA/MQA: query
    head h reads KV head ``h // group`` (group = n_heads // n_kv_heads),
    so the producer K offset uses the *grouped* head index — monotone in
    h, which keeps the analytical max-corner argument intact."""

    def __init__(self, seq: int, hd: int, kind: str, group: int = 1):
        assert kind in ("qk_weight", "av_weight")
        assert group >= 1
        self.seq, self.hd, self.kind = seq, hd, kind
        self.group = group

    def key(self):
        return ("weight", self.kind, self.seq, self.hd, self.group)

    def to_producer(self, producer, consumer, lo, hi):
        seq, hd = self.seq, self.hd
        r_lo, r_hi = lo["P"], hi["P"] - 1
        h_lo, h_hi = (r_lo // seq) // self.group, \
            (r_hi // seq) // self.group
        ready0 = np.zeros(r_lo.shape, dtype=bool)
        if self.kind == "qk_weight":
            # weight element (k=n, c) of head h <- k_proj output (P=n,
            # K=(h//group)*hd+c)
            k_lo = h_lo * hd + lo["C"]
            k_hi = h_hi * hd + hi["C"] - 1
            return ({"K": k_lo, "P": lo["K"], "Q": np.zeros_like(r_lo)},
                    {"K": k_hi + 1, "P": hi["K"], "Q": np.ones_like(r_lo)},
                    ready0)
        # av_weight: weight element (k=j, c=m) of head h <- v_proj output
        # (P=m, K=(h//group)*hd+j)
        k_lo = h_lo * hd + lo["K"]
        k_hi = h_hi * hd + hi["K"] - 1
        return ({"K": k_lo, "P": lo["C"], "Q": np.zeros_like(r_lo)},
                {"K": k_hi + 1, "P": hi["C"], "Q": np.ones_like(r_lo)},
                ready0)


class FullMap(CoordMap):
    """Conservative edge: every consumer tile needs the producer's ENTIRE
    output before it can start. Used where the element-level mapping has
    no affine tile-to-tile structure — MoE routing/dispatch (which tokens
    land in which expert slot depends on router *values*), expert-combine
    scatter-adds, KV-cache appends in decode, SSD inter-chunk state
    recurrences and token<->spatial flattenings. The projected rectangle
    is the full [K, P, Q] output, so the ready step is the producer's
    last step under both the analytical and exhaustive analyses."""

    def key(self):
        return ("full",)

    def to_producer(self, producer, consumer, lo, hi):
        z = np.zeros_like(lo["P"])
        ready0 = np.zeros(z.shape, dtype=bool)
        return ({"K": z, "P": z, "Q": z},
                {"K": np.full_like(z, producer.K),
                 "P": np.full_like(z, producer.P),
                 "Q": np.full_like(z, producer.Q)},
                ready0)


@dataclasses.dataclass
class Edge:
    """Dependency edge: this layer consumes ``producer``'s outputs."""

    producer: int                 # index into the network's layer list
    cmap: CoordMap = dataclasses.field(default_factory=IdentityMap)


# ---------------------------------------------------------------------------
# Consumer tile rectangles (lo/hi arrays over the (bank, step) grid).
# ---------------------------------------------------------------------------

def consumer_tiles(m_c: Mapping) -> Tuple[Rect, Rect]:
    return rect_bounds(m_c)


# ---------------------------------------------------------------------------
# Ready-step computation: analytical (the paper) vs exhaustive (OverlaPIM).
# ---------------------------------------------------------------------------

def rect_loop_groups(m_p: Mapping):
    """Group ``rect_loops`` per output dim as ``(size, block, weight)``
    triples, plus the constant contribution of reduction/batch dims (taken
    at their last iteration). Shared preamble of ``max_step_in_rect`` and
    the engine's deduplicated scans."""
    per_dim: Dict[str, list] = {}
    const = 0
    for lp, blk, tstride, bstride in m_p.rect_loops:
        w = 0 if lp.spatial else tstride
        if lp.dim in OUTPUT_DIMS:
            per_dim.setdefault(lp.dim, []).append((lp.size, blk, w))
        else:  # reduction / batch dims: last iteration
            const += w * (lp.size - 1)
    return per_dim, const


def digit_scan(loops, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Exact maximum of one dim's weighted mixed-radix digit sum over the
    coordinate interval [lo, hi] (inclusive), via a closed-form digit scan
    (families: x==hi, x==lo, follow-hi-then-drop, follow-lo-then-raise —
    each with a free max suffix). This is the single canonical scan kernel:
    ``max_step_in_rect`` runs it on full rect arrays, the engine on
    deduplicated intervals."""
    shape = lo.shape
    m = len(loops)
    if m == 1:
        # single digit: lo <= hi implies digit(lo) <= digit(hi) (no wrap,
        # the loop spans the whole dim) and families 3/4 never beat w*b
        n1, blk, w1 = loops[0]
        return float(w1) * ((hi // blk) % n1)
    a = [(lo // blk) % n for (n, blk, w) in loops]
    b = [(hi // blk) % n for (n, blk, w) in loops]
    w = [float(wl) for (_, _, wl) in loops]
    n = [nl for (nl, _, _) in loops]
    # prefix weighted values (exclusive) + prefix digit equality
    pre_hi = np.zeros(shape)
    pre_lo = np.zeros(shape)
    eq = np.ones(shape, dtype=bool)
    # suffix free maxima (exclusive of position j)
    suf = [np.zeros(shape) for _ in range(m + 1)]
    for j in range(m - 1, -1, -1):
        suf[j] = suf[j + 1] + w[j] * (n[j] - 1)
    val_hi = np.zeros(shape)
    val_lo = np.zeros(shape)
    for j in range(m):
        val_hi = val_hi + w[j] * b[j]
        val_lo = val_lo + w[j] * a[j]
    best = np.maximum(val_hi, val_lo)
    for j in range(m):
        # family 3: follow hi's digits, drop at j, free suffix
        f3_ok = (b[j] >= 1) & (~eq | (b[j] - 1 > a[j]))
        f3 = pre_hi + w[j] * (b[j] - 1) + suf[j + 1]
        best = np.where(f3_ok, np.maximum(best, f3), best)
        # family 4: follow lo's digits, raise at j, free suffix
        f4_ok = (~eq) & (a[j] + 1 <= n[j] - 1)
        f4 = pre_lo + w[j] * (n[j] - 1) + suf[j + 1]
        best = np.where(f4_ok, np.maximum(best, f4), best)
        pre_hi = pre_hi + w[j] * b[j]
        pre_lo = pre_lo + w[j] * a[j]
        eq = eq & (a[j] == b[j])
    return best


def max_step_in_rect(m_p: Mapping, plo: Rect, phi: Rect) -> np.ndarray:
    """Latest producer time step touching the rectangle [plo, phi).

    The step index is separable across dims: T = sum_d T_d(coord_d) with
    T_d a weighted mixed-radix digit sum (temporal loops weigh their
    Eq (1) stride G, spatial loops weigh 0); per dim ``digit_scan`` takes
    the exact interval maximum. Reduction dims contribute their last
    iteration (output complete only after the whole reduction). Vectorized
    over arbitrary interval arrays."""
    per_dim, const = rect_loop_groups(m_p)
    shape = np.broadcast(*[plo[d] for d in OUTPUT_DIMS]).shape
    total = np.full(shape, float(const))
    for d, loops in per_dim.items():
        lo = np.broadcast_to(plo[d], shape)
        hi = np.broadcast_to(phi[d], shape) - 1     # inclusive
        total = total + digit_scan(loops, lo, hi)
    return total.astype(np.int64)


def ready_steps_analytical(m_p: Mapping, m_c: Mapping,
                           cmap: Optional[CoordMap] = None,
                           tiles: Optional[Tuple[Rect, Rect]] = None):
    """Per consumer (bank, step): the latest producer step that finishes
    any of its inputs, plus the always-ready mask. O(consumer spaces),
    fully vectorized (paper Section IV-H)."""
    cmap = cmap or IdentityMap()
    lo, hi = tiles if tiles is not None else consumer_tiles(m_c)
    plo, phi, ready0 = cmap.to_producer(m_p.layer, m_c.layer, lo, hi)
    plo = {d: np.clip(plo[d], 0, m_p.layer.dim(d) - 1)
           for d in OUTPUT_DIMS}
    phi = {d: np.clip(phi[d], 1, m_p.layer.dim(d)) for d in OUTPUT_DIMS}
    step = max_step_in_rect(m_p, plo, phi)
    return step, ready0


def ready_steps_exhaustive(m_p: Mapping, m_c: Mapping,
                           cmap: Optional[CoordMap] = None):
    """OverlaPIM baseline: compare every consumer space against every
    producer space (O(N*M) rectangle intersections, pure Python)."""
    cmap = cmap or IdentityMap()
    lo, hi = consumer_tiles(m_c)
    plo, phi, ready0 = cmap.to_producer(m_p.layer, m_c.layer, lo, hi)
    pds = generate_analytical(m_p)
    nbc, ntc = m_c.n_banks, m_c.n_steps
    step = np.zeros((nbc, ntc), dtype=np.int64)
    offs, ext = pds.offsets, pds.extent
    for bc in range(nbc):
        for tc in range(ntc):
            if ready0[bc, tc]:
                continue
            best_t = -1
            for bp in range(pds.n_banks):
                for tp in range(pds.n_steps):
                    ok = True
                    for d in OUTPUT_DIMS:
                        o = int(offs[d][bp, tp])
                        if not (o < phi[d][bc, tc]
                                and o + ext[d] > plo[d][bc, tc]):
                            ok = False
                            break
                    if ok and tp > best_t:
                        best_t = tp
            step[bc, tc] = best_t
    # a space whose projected rectangle intersects NO producer space needs
    # no producer data: ready at t=0, like the analytical path's ready0
    # mask. Leaving the -1 search sentinel would make ``fin_step[step]``
    # wrap to the LAST producer step ("ready at producer completion").
    none = step < 0
    if none.any():
        step[none] = 0
        ready0 = ready0 | none
    return step, ready0


# ---------------------------------------------------------------------------
# Scheduling with ready times.
# ---------------------------------------------------------------------------

def schedule_with_ready(ready_ns: np.ndarray, step_ns: float,
                        start_floor: float = 0.0) -> np.ndarray:
    """Finish time of each (bank, step) given per-space ready times.

    Per bank: ``end[t] = max(end[t-1], ready[t], floor) + L`` — closed form
    via running max (vectorized, O(n))."""
    nb, nt = ready_ns.shape
    t = np.arange(nt, dtype=np.float64)
    eff = np.maximum(ready_ns, start_floor)
    base = np.maximum.accumulate(eff - t[None, :] * step_ns, axis=1)
    return base + (t[None, :] + 1) * step_ns


def overlapped_end(ready_ns: np.ndarray, step_ns: float,
                   start_floor: float = 0.0) -> float:
    fin = schedule_with_ready(ready_ns, step_ns, start_floor)
    return float(fin[:, -1].max()) if fin.size else 0.0


def stream_tail_fraction(mapping: Mapping, samples: int = 5) -> float:
    """Mean completion fraction of a grid of output elements.

    ~0.5 for a raster-streaming production order (outputs complete
    uniformly over time — overlap-friendly for the NEXT layer), ~1.0 for
    reduction-outermost orders where every output completes only at the
    end. Used by the forward search as a successor-friendliness proxy
    (Section IV-K's observation that per-layer-optimal mappings are biased
    against later layers)."""
    layer = mapping.layer
    ks = np.full(samples * samples, layer.K - 1)
    ps = np.repeat(np.linspace(0, layer.P - 1, samples).astype(np.int64),
                   samples)
    qs = np.tile(np.linspace(0, layer.Q - 1, samples).astype(np.int64),
                 samples)
    _, steps = locate_finish(mapping, {"K": ks, "P": ps, "Q": qs})
    return float(steps.mean() + 1) / mapping.n_steps


def stream_tail_fractions(mappings, samples: int = 5) -> np.ndarray:
    """``stream_tail_fraction`` vectorized over K candidate mappings of one
    layer. The sampled output-coordinate grid depends only on the layer, so
    it is built once; per candidate only the temporal digit location runs
    (the bank half of ``locate_finish`` is dead weight for the tail).
    Bit-identical to the scalar function: the located steps are exact
    integers and the mean of int64 is order-independent."""
    if not len(mappings):
        return np.zeros(0, dtype=np.float64)
    layer = mappings[0].layer
    ps = np.repeat(np.linspace(0, layer.P - 1, samples).astype(np.int64),
                   samples)
    qs = np.tile(np.linspace(0, layer.Q - 1, samples).astype(np.int64),
                 samples)
    coords = {"P": ps, "Q": qs}
    out = np.empty(len(mappings), dtype=np.float64)
    for k, m in enumerate(mappings):
        # K samples are the constant K-1 and reduction/batch dims take
        # their last iteration, so only P/Q loops vary across the sample
        # grid — fold everything else into an integer constant (the summed
        # step indices are the same exact integers as the full loop)
        const = 0
        step = None
        for lp, blk, tstride, bstride in m.rect_loops:
            if lp.spatial:
                continue
            if lp.dim == "K":
                const += int(((layer.K - 1) // blk) % lp.size) * tstride
            elif lp.dim in coords:
                c = ((coords[lp.dim] // blk) % lp.size) * tstride
                step = c if step is None else step + c
            else:               # reduction / batch dims: last iteration
                const += (lp.size - 1) * tstride
        if step is None:
            out[k] = float(const + 1) / m.n_steps
        else:
            out[k] = float((step + const).mean() + 1) / m.n_steps
    return out
