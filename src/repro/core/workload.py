"""DNN workload descriptions as 7D loop nests (Timeloop convention).

The paper (Section IV-E) uses the conventional 7D representation of a conv
layer: R/S = filter height/width, P/Q = output height/width, C = input
channels, K = output channels, N = batch. Matrix multiplies (FC, attention
matmuls, BERT Section VI) are degenerate cases with R=S=Q=1 (output rows in
P, output cols in K, reduction in C).

Output data space: [K, P, Q]; input data space: [C, P+R-1, Q+S-1] (stride 1)
or generally [C, (P-1)*stride+R, (Q-1)*stride+S]; weights: [K, C, R, S].
"""
from __future__ import annotations

import dataclasses
from typing import List

DIMS = ("K", "C", "P", "Q", "R", "S", "N")
OUTPUT_DIMS = ("K", "P", "Q")  # N folded into P for matmuls / ignored (paper IV-E)
REDUCTION_DIMS = ("C", "R", "S")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One DNN layer as a 7D loop nest."""

    name: str
    K: int  # output channels
    C: int  # input channels
    P: int  # output height
    Q: int  # output width
    R: int = 1  # filter height
    S: int = 1  # filter width
    N: int = 1  # batch (folded; kept for completeness)
    stride: int = 1
    pad: int = 0

    def dim(self, d: str) -> int:
        return getattr(self, d)

    @property
    def macs(self) -> int:
        return self.N * self.K * self.C * self.P * self.Q * self.R * self.S

    @property
    def output_elems(self) -> int:
        return self.N * self.K * self.P * self.Q

    @property
    def input_shape(self) -> tuple:
        ih = (self.P - 1) * self.stride + self.R
        iw = (self.Q - 1) * self.stride + self.S
        return (self.C, ih, iw)

    @property
    def input_elems(self) -> int:
        c, h, w = self.input_shape
        return self.N * c * h * w

    @property
    def weight_elems(self) -> int:
        return self.K * self.C * self.R * self.S

    def output_size(self) -> int:
        """P*Q*K — paper's "largest output size" Middle heuristic."""
        return self.P * self.Q * self.K

    def overall_size(self) -> int:
        """P*Q*C*K — paper's "largest overall size" Middle heuristic."""
        return self.P * self.Q * self.C * self.K


def conv(name, C, K, hw, RS=3, stride=1, pad=None) -> LayerSpec:
    if pad is None:
        pad = RS // 2
    return LayerSpec(name=name, K=K, C=C, P=hw, Q=hw, R=RS, S=RS,
                     stride=stride, pad=pad)


def matmul(name, M, Kdim, Nout, batch=1) -> LayerSpec:
    """GEMM C[M,Nout] = A[M,Kdim] @ B[Kdim,Nout] as degenerate conv.

    Paper Section VI: "by setting R, S, P, and Q to 1, matrix-matrix
    multiplications can be expressed" — we keep output rows in P so the
    mapper can tile them, which is the same degeneracy (R=S=1, Q=1).
    Head-batched matmuls fold the head count into M.
    """
    return LayerSpec(name=name, K=Nout, C=Kdim, P=M * batch, Q=1, R=1, S=1,
                     stride=1, pad=0)


# ---------------------------------------------------------------------------
# Networks evaluated in the paper (Section V: ResNet-18, VGG-16, ResNet-50;
# Section VI: one BERT encoder block).
# ---------------------------------------------------------------------------

def vgg16() -> List[LayerSpec]:
    """13 conv layers of VGG-16 (paper reports 13 layers)."""
    cfg = [
        (3, 64, 224), (64, 64, 224),
        (64, 128, 112), (128, 128, 112),
        (128, 256, 56), (256, 256, 56), (256, 256, 56),
        (256, 512, 28), (512, 512, 28), (512, 512, 28),
        (512, 512, 14), (512, 512, 14), (512, 512, 14),
    ]
    return [conv(f"conv{i+1}", c, k, hw) for i, (c, k, hw) in enumerate(cfg)]


def resnet18() -> List[LayerSpec]:
    """20 layers (paper: "Layer 2 to Layer 20"): conv1 + 16 block convs +
    3 downsample 1x1 convs."""
    layers = [LayerSpec("conv1", K=64, C=3, P=112, Q=112, R=7, S=7,
                        stride=2, pad=3)]
    # stage 1: 56x56, 64ch — 2 basic blocks
    for b in range(2):
        layers.append(conv(f"s1b{b}c1", 64, 64, 56))
        layers.append(conv(f"s1b{b}c2", 64, 64, 56))
    # stages 2-4 with downsample conv in first block
    stage = [(64, 128, 28), (128, 256, 14), (256, 512, 7)]
    for si, (cin, cout, hw) in enumerate(stage, start=2):
        layers.append(conv(f"s{si}b0c1", cin, cout, hw, stride=2))
        layers.append(conv(f"s{si}b0c2", cout, cout, hw))
        layers.append(LayerSpec(f"s{si}b0ds", K=cout, C=cin, P=hw, Q=hw,
                                R=1, S=1, stride=2, pad=0))
        layers.append(conv(f"s{si}b1c1", cout, cout, hw))
        layers.append(conv(f"s{si}b1c2", cout, cout, hw))
    assert len(layers) == 20
    return layers


def resnet50() -> List[LayerSpec]:
    """49 conv layers: conv1 + 16 bottleneck blocks x 3 convs (downsample
    convs excluded; paper Section IV-J argues skip layers complete within
    the block's execution and do not affect total latency)."""
    layers = [LayerSpec("conv1", K=64, C=3, P=112, Q=112, R=7, S=7,
                        stride=2, pad=3)]
    stages = [  # (n_blocks, mid_ch, out_ch, hw)
        (3, 64, 256, 56), (4, 128, 512, 28), (6, 256, 1024, 14),
        (3, 512, 2048, 7),
    ]
    cin = 64
    for si, (nb, mid, cout, hw) in enumerate(stages, start=1):
        for b in range(nb):
            stride = 2 if (b == 0 and si > 1) else 1
            layers.append(LayerSpec(f"s{si}b{b}c1", K=mid, C=cin, P=hw,
                                    Q=hw, R=1, S=1, stride=stride, pad=0))
            layers.append(conv(f"s{si}b{b}c2", mid, mid, hw))
            layers.append(LayerSpec(f"s{si}b{b}c3", K=cout, C=mid, P=hw,
                                    Q=hw, R=1, S=1, stride=1, pad=0))
            cin = cout
    assert len(layers) == 49
    return layers


def bert_encoder(seq: int = 512, d_model: int = 768, heads: int = 12,
                 d_ff: int = 3072) -> List[LayerSpec]:
    """One BERT-base encoder block as a chain of matmul layers (Section VI).

    Softmax/LN are elementwise and excluded (paper: "FC and FFN layers ...
    account for a majority of the computation").
    """
    hd = d_model // heads
    return [
        matmul("q_proj", seq, d_model, d_model),
        matmul("k_proj", seq, d_model, d_model),
        matmul("v_proj", seq, d_model, d_model),
        matmul("qk", seq, hd, seq, batch=heads),
        matmul("av", seq, seq, hd, batch=heads),
        matmul("out_proj", seq, d_model, d_model),
        matmul("ffn1", seq, d_model, d_ff),
        matmul("ffn2", seq, d_ff, d_model),
    ]


NETWORKS = {
    "resnet18": resnet18,
    "vgg16": vgg16,
    "resnet50": resnet50,
    "bert_encoder": bert_encoder,
}


def get_network(name: str) -> List[LayerSpec]:
    """Layers of a core network, or of a zoo scenario string
    (``repro.workloads`` grammar ``<arch>[:phase][@length][xblocks]``,
    e.g. ``deepseek_moe_16b:prefill@2048``). Raises ``KeyError`` listing
    both namespaces for unknown names."""
    if name in NETWORKS:
        return NETWORKS[name]()
    try:  # lazy: the lowering layer imports the model zoo (jax)
        from ..workloads import scenario_layers
    except ImportError:
        raise KeyError(
            f"unknown network {name!r}; have {sorted(NETWORKS)} "
            "(zoo scenarios unavailable: repro.workloads failed to "
            "import)") from None
    return scenario_layers(name)   # KeyError on unknown arch
