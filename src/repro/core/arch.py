"""PIM architecture configuration (paper Section IV-B, Fig 6/7, Table I).

A hierarchy of memory levels, top (whole memory) to bottom (columns inside a
bank). Each level has a fanout (instances per parent), word width, optional
read/write bandwidth (bytes per ns), and — at the compute level — PIM op
latencies (ns) for bit-serial add/mul.

The analysis level (paper Section IV-H) is the Bank: data spaces are tracked
per (bank, time-step); column parallelism is folded into the per-step
latency via the performance model.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Level:
    name: str
    fanout: int = 1                 # instances per parent level
    word_bits: int = 16
    read_bw: Optional[float] = None   # bytes / ns
    write_bw: Optional[float] = None
    pim_ops: Optional[Dict[str, float]] = None  # op -> latency ns

    def __hash__(self):
        # the generated hash would choke on the pim_ops dict
        ops = None if self.pim_ops is None \
            else tuple(sorted(self.pim_ops.items()))
        return hash((self.name, self.fanout, self.word_bits,
                     self.read_bw, self.write_bw, ops))

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        if d["pim_ops"] is not None:
            d["pim_ops"] = dict(sorted(d["pim_ops"].items()))
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "Level":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class HBMTiming:
    """Table I — HBM2 timing (ns) and energy (pJ)."""

    t_rc: float = 45.0
    t_rcd: float = 16.0
    t_ras: float = 29.0
    t_cl: float = 16.0
    t_rrd: float = 2.0
    t_wr: float = 16.0
    t_ccd_s: float = 2.0
    t_ccd_l: float = 4.0
    e_act: float = 909.0
    e_pre_gsa: float = 1.51
    e_post_gsa: float = 1.17
    e_io: float = 0.80

    @property
    def t_aap(self) -> float:
        """One activate-activate-precharge (triple-row activation) step."""
        return self.t_rc  # dominant row-cycle time


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """Hierarchical PIM architecture.

    ``levels`` is ordered top -> bottom; ``target_level`` names the level at
    which data spaces / overlap are analyzed (paper: Bank).
    """

    name: str
    levels: Tuple[Level, ...]
    target_level: str = "Bank"
    word_bits: int = 16
    timing: HBMTiming = dataclasses.field(default_factory=HBMTiming)
    host_bus_gbps: float = 256.0  # GB/s host bus connecting HBM stacks

    def __hash__(self):
        return hash(self.to_key())

    def to_dict(self) -> Dict:
        """JSON-safe representation capturing every field (round-trips via
        ``from_dict``)."""
        return {
            "name": self.name,
            "levels": [lv.to_dict() for lv in self.levels],
            "target_level": self.target_level,
            "word_bits": self.word_bits,
            "timing": dataclasses.asdict(self.timing),
            "host_bus_gbps": self.host_bus_gbps,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ArchSpec":
        return cls(
            name=d["name"],
            levels=tuple(Level.from_dict(lv) for lv in d["levels"]),
            target_level=d["target_level"],
            word_bits=d["word_bits"],
            timing=HBMTiming(**d["timing"]),
            host_bus_gbps=d["host_bus_gbps"],
        )

    @functools.cached_property
    def _key(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def to_key(self) -> str:
        """Stable content key: equal-content specs — including specs built
        in different processes or round-tripped through ``to_dict`` — share
        the key. Used by the engine's per-arch cache bundles, ``PerfCache``
        and the DSE run journal (``repro.dse.persist``)."""
        return self._key

    def level_index(self, name: str) -> int:
        for i, lv in enumerate(self.levels):
            if lv.name == name:
                return i
        raise KeyError(name)

    @property
    def target_index(self) -> int:
        return self.level_index(self.target_level)

    def instances_at(self, idx: int) -> int:
        """Total instances of level ``idx`` (product of fanouts above)."""
        n = 1
        for lv in self.levels[: idx + 1]:
            n *= lv.fanout
        return n

    @property
    def n_target_instances(self) -> int:
        return self.instances_at(self.target_index)

    @property
    def compute_level(self) -> Level:
        return self.levels[-1]

    @property
    def columns_per_target(self) -> int:
        """Compute lanes under one analysis-level instance."""
        n = 1
        for lv in self.levels[self.target_index + 1:]:
            n *= lv.fanout
        return n

    def op_latency(self, op: str) -> float:
        """Latency (ns) of a PIM op at the compute level.

        Falls back to the derived bit-serial AAP model (paper Section IV-C:
        a full addition is 4n+1 AAP operations; a multiplication is n
        sequential additions) when the config does not pin a latency.
        """
        ops = self.compute_level.pim_ops or {}
        if op in ops:
            return ops[op]
        n = self.word_bits
        add = (4 * n + 1) * self.timing.t_aap
        if op == "add":
            return add
        if op == "mul":
            return n * add
        raise KeyError(op)

    @property
    def word_bytes(self) -> float:
        return self.word_bits / 8.0

    def movement_ns_per_byte(self) -> float:
        """Intra-memory data movement cost via the tightest configured BW."""
        bws = [lv.read_bw for lv in self.levels if lv.read_bw]
        bw = min(bws) if bws else 16.0
        return 1.0 / bw


def dram_pim(channels_per_layer: int = 2, banks_per_channel: int = 8,
             columns_per_bank: int = 8192, word_bits: int = 16) -> ArchSpec:
    """HBM2 DRAM-based bit-serial row-parallel PIM (Fig 6, Table I).

    Default allocation per layer: 2 channels x 8 banks (Section V-A3 /
    Section V-E uses 1/2/4-channel settings).
    """
    levels = (
        Level("DRAM", fanout=1, word_bits=word_bits),
        Level("Channel", fanout=channels_per_layer, word_bits=word_bits,
              read_bw=16.0, write_bw=16.0),
        Level("Bank", fanout=banks_per_channel, word_bits=word_bits,
              read_bw=16.0, write_bw=16.0),
        Level("Column", fanout=columns_per_bank, word_bits=1,
              pim_ops={"add": 196.0, "mul": 980.0}),
    )
    return ArchSpec(name=f"dram_pim_{channels_per_layer}ch", levels=levels,
                    target_level="Bank", word_bits=word_bits)


def reram_pim(tiles_per_layer: int = 2, blocks_per_tile: int = 64,
              columns_per_block: int = 1024, word_bits: int = 16) -> ArchSpec:
    """FloatPIM-style ReRAM digital PIM (Fig 7)."""
    levels = (
        Level("ReRAM", fanout=1, word_bits=word_bits,
              read_bw=1024.0, write_bw=1024.0),
        Level("Tile", fanout=tiles_per_layer, word_bits=word_bits,
              read_bw=16.0, write_bw=16.0),
        Level("Bank", fanout=blocks_per_tile, word_bits=word_bits,
              read_bw=16.0, write_bw=16.0),
        Level("Column", fanout=columns_per_block, word_bits=1,
              pim_ops={"add": 442.0, "mul": 696.0}),
    )
    return ArchSpec(name=f"reram_pim_{tiles_per_layer}t", levels=levels,
                    target_level="Bank", word_bits=word_bits)


def tpu_spatial(cores: int = 8, lanes: int = 128 * 128) -> ArchSpec:
    """A TPU-like spatial config: cores <-> banks, MXU lanes <-> columns.

    Used to let the same overlap mapper emit TPU pipeline-stage schedules
    (DESIGN.md Section 3, adaptation level 3). Latencies model one MXU MAC
    slot rather than bit-serial AAPs.
    """
    levels = (
        Level("Pod", fanout=1),
        Level("Chip", fanout=1, read_bw=819.0, write_bw=819.0),
        Level("Bank", fanout=cores, read_bw=819.0, write_bw=819.0),
        Level("Column", fanout=lanes, word_bits=16,
              pim_ops={"add": 0.00107, "mul": 0.00107}),
    )
    return ArchSpec(name=f"tpu_spatial_{cores}c", levels=levels,
                    target_level="Bank", word_bits=16)


ARCH_PRESETS = {
    "dram_pim": dram_pim,
    "reram_pim": reram_pim,
    "tpu_spatial": tpu_spatial,
}
