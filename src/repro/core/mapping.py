"""Timeloop-style mappings: per-level loop blocks over the 7D nest.

A mapping assigns, to every architecture level, an ordered block of loops
``(dim, size, spatial?)`` (outer -> inner). Spatial loops in the block of
level *i* distribute iterations across instances of level *i+1*
(``parallel_for``); temporal loops sequence them in time (``for``).

Conventions (see DESIGN.md Section 5):
  * perfect factorization: per dim, the product of loop sizes across all
    blocks equals the dim size, so data spaces are exact hyper-rectangles;
  * reduction dims (C, R, S) may only be spatial at the target (bank) block
    — i.e. partial sums may be spread across *columns* (charged a reduction
    movement cost) but never across banks/channels, keeping bank-level
    output data spaces well defined;
  * within the target block all temporal loops precede all spatial loops,
    keeping bank-level data spaces contiguous rectangles.
"""
from __future__ import annotations

import dataclasses
import functools
import random
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .arch import ArchSpec
from .workload import DIMS, OUTPUT_DIMS, REDUCTION_DIMS, LayerSpec


@dataclasses.dataclass(frozen=True)
class Loop:
    dim: str
    size: int
    spatial: bool = False

    def __repr__(self):
        tag = "par" if self.spatial else "for"
        return f"{tag}({self.dim}:{self.size})"


# process-global intern table: token -> unique per (layer, blocks) content.
# Deliberately unbounded — tokens must never be reused (engine caches key on
# them), and entries are tiny tuples bounded by the distinct mappings a
# process ever explores.
_CACHE_KEY_INTERN: Dict = {}


@dataclasses.dataclass(frozen=True)
class Mapping:
    layer: LayerSpec
    arch: ArchSpec
    # one loop block per arch level, outer -> inner within each block
    blocks: Tuple[Tuple[Loop, ...], ...]

    # -- validation ---------------------------------------------------------

    def validate(self) -> None:
        arch, layer = self.arch, self.layer
        if len(self.blocks) != len(arch.levels):
            raise ValueError("one loop block per architecture level required")
        prod: Dict[str, int] = {d: 1 for d in DIMS}
        for li, block in enumerate(self.blocks):
            spatial_prod = 1
            seen_spatial = False
            for lp in block:
                if lp.size < 1:
                    raise ValueError(f"loop size < 1: {lp}")
                prod[lp.dim] *= lp.size
                if lp.spatial:
                    seen_spatial = True
                    spatial_prod *= lp.size
                    if li >= len(arch.levels) - 1:
                        raise ValueError("innermost level cannot be spatial")
                    if (lp.dim in REDUCTION_DIMS
                            and li != arch.target_index):
                        raise ValueError(
                            f"reduction dim {lp.dim} spatial above target")
                elif seen_spatial and li == arch.target_index:
                    raise ValueError(
                        "target block must order temporal before spatial")
            if li < len(arch.levels) - 1:
                if spatial_prod > arch.levels[li + 1].fanout:
                    raise ValueError(
                        f"spatial fanout {spatial_prod} exceeds "
                        f"{arch.levels[li + 1].name} fanout "
                        f"{arch.levels[li + 1].fanout}")
        for d in DIMS:
            if prod[d] != layer.dim(d):
                raise ValueError(
                    f"dim {d}: factors multiply to {prod[d]} != "
                    f"{layer.dim(d)}")

    # -- derived schedule structure -----------------------------------------

    @functools.cached_property
    def nest(self) -> List[Tuple[int, Loop]]:
        """All loops outer -> inner as (level_index, loop)."""
        out = []
        for li, block in enumerate(self.blocks):
            for lp in block:
                out.append((li, lp))
        return out

    @functools.cached_property
    def time_loops(self) -> List[Loop]:
        """Temporal loops that advance the bank-level time step, in nest
        order: temporal loops of blocks 0..target."""
        t = self.arch.target_index
        return [lp for li, lp in self.nest if li <= t and not lp.spatial]

    @functools.cached_property
    def space_loops(self) -> List[Loop]:
        """Spatial loops above the target level, in nest order — they define
        the bank coordinate."""
        t = self.arch.target_index
        return [lp for li, lp in self.nest if li < t and lp.spatial]

    @functools.cached_property
    def column_loops(self) -> List[Loop]:
        """Loops inside a bank step: target-block spatial (across columns)
        plus all loops of levels below the target."""
        t = self.arch.target_index
        out = [lp for li, lp in self.nest if li == t and lp.spatial]
        out += [lp for li, lp in self.nest if li > t]
        return out

    @property
    def n_steps(self) -> int:
        n = 1
        for lp in self.time_loops:
            n *= lp.size
        return n

    @property
    def n_banks(self) -> int:
        n = 1
        for lp in self.space_loops:
            n *= lp.size
        return n

    @property
    def n_columns_used(self) -> int:
        t = self.arch.target_index
        n = 1
        for li, lp in self.nest:
            if li == t and lp.spatial:
                n *= lp.size
        return n

    @functools.cached_property
    def time_strides(self) -> List[int]:
        """Paper Eq (1): G(n) = product of iteration counts of temporal
        loops inner to n — the time-step increment of one iteration of
        loop n."""
        strides = []
        rest = self.n_steps
        for lp in self.time_loops:
            rest //= lp.size
            strides.append(rest)
        return strides

    @functools.cached_property
    def space_strides(self) -> List[int]:
        strides = []
        rest = self.n_banks
        for lp in self.space_loops:
            rest //= lp.size
            strides.append(rest)
        return strides

    @functools.cached_property
    def tile_extent(self) -> Dict[str, int]:
        """Extent per dim of one (bank, step) data space rectangle."""
        ext = {d: self.layer.dim(d) for d in DIMS}
        t = self.arch.target_index
        for li, lp in self.nest:
            if li < t or (li == t and not lp.spatial):
                ext[lp.dim] //= lp.size
        return ext

    @functools.cached_property
    def rect_loops(self) -> List[Tuple[Loop, int, int, int]]:
        """Rectangle-defining loops outer->inner with their per-dim block
        size after the split, time stride (0 for spatial) and bank stride
        (0 for temporal).

        Returns tuples ``(loop, dim_block_size, time_stride, bank_stride)``
        where ``dim_block_size`` is the sub-block extent of ``loop.dim``
        produced by this loop (i.e. offset contribution per iteration).
        """
        t = self.arch.target_index
        cur = {d: self.layer.dim(d) for d in DIMS}
        tstrides = iter(self.time_strides)
        sstrides = iter(self.space_strides)
        out = []
        for li, lp in self.nest:
            if li > t or (li == t and lp.spatial):
                continue
            cur[lp.dim] //= lp.size
            if lp.spatial:
                out.append((lp, cur[lp.dim], 0, next(sstrides)))
            else:
                out.append((lp, cur[lp.dim], next(tstrides), 0))
        return out

    @functools.cached_property
    def cache_key(self) -> int:
        """Content-based identity for memoization: an interned token for
        (layer spec, loop blocks) — equal-content mappings share a token,
        and later cache lookups hash a small int instead of the whole
        nest. ``ArchSpec`` holds unhashable members (per-level op dicts) so
        callers cache per-arch (see ``core.engine``); two mappings with
        equal keys under the same arch are behaviourally identical."""
        content = (self.layer, self.blocks)
        token = _CACHE_KEY_INTERN.get(content)
        if token is None:
            token = _CACHE_KEY_INTERN[content] = len(_CACHE_KEY_INTERN)
        return token

    def macs_per_step(self) -> int:
        e = self.tile_extent
        m = 1
        for d in DIMS:
            m *= e[d]
        return m

    def pretty(self) -> str:
        lines = []
        for li, block in enumerate(self.blocks):
            name = self.arch.levels[li].name
            body = " ".join(repr(lp) for lp in block) or "-"
            lines.append(f"{name:>8}: {body}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Factorization utilities + random mapping generation (mapper substrate)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def divisors(n: int) -> Tuple[int, ...]:
    out = [d for d in range(1, int(n ** 0.5) + 1) if n % d == 0]
    out += [n // d for d in reversed(out) if d * d != n]
    return tuple(out)


def random_divisor_le(n: int, cap: int, rng: random.Random) -> int:
    opts = [d for d in divisors(n) if d <= cap]
    return rng.choice(opts)


# slots: (level_index, spatial?) outer->inner; filled per dim
def _slot_order(arch: ArchSpec) -> List[Tuple[int, bool]]:
    slots: List[Tuple[int, bool]] = []
    for li in range(len(arch.levels)):
        slots.append((li, False))                  # temporal at level li
        if li < len(arch.levels) - 1:
            slots.append((li, True))               # spatial -> level li+1
    return slots


def random_mapping(layer: LayerSpec, arch: ArchSpec, rng: random.Random,
                   max_steps: int = 65536,
                   max_tries: int = 64,
                   stream: Optional[bool] = None) -> Mapping:
    """Sample a random valid mapping (rejection sampling with repair).

    Search-space shape follows the paper: tiling factors per dim per level
    slot + loop permutation per block. ``stream=True`` forces the
    overlap-friendly temporal order (half of candidates by default).
    """
    t = arch.target_index
    n_levels = len(arch.levels)
    for _ in range(max_tries):
        # factor assignment: dim -> {slot -> factor}
        per_slot: Dict[Tuple[int, bool], Dict[str, int]] = {
            s: {} for s in _slot_order(arch)}
        ok = True
        for d in DIMS:
            rem = layer.dim(d)
            # choose spatial splits top-down first (subject to fanout)
            for li in range(n_levels - 1):
                cap = arch.levels[li + 1].fanout
                if d in REDUCTION_DIMS and li != t:
                    f = 1
                elif rng.random() < 0.5:
                    f = random_divisor_le(rem, cap, rng)
                else:
                    f = 1
                per_slot[(li, True)][d] = f
                rem //= f
            # distribute the remainder across temporal slots
            for li in range(n_levels):
                if li == n_levels - 1:
                    f = rem  # innermost absorbs the rest
                else:
                    f = random_divisor_le(rem, rem, rng)
                per_slot[(li, False)][d] = f
                rem //= f
            if rem != 1:
                ok = False
                break
        if not ok:
            continue
        # fanout constraints (joint across dims) + step bound, with repair:
        for li in range(n_levels - 1):
            cap = arch.levels[li + 1].fanout
            sl = per_slot[(li, True)]
            dims_sorted = sorted(sl, key=lambda d: -sl[d])
            while _prod(sl.values()) > cap:
                dd = dims_sorted[0]
                # demote largest spatial factor to temporal at same level
                per_slot[(li, False)][dd] *= sl[dd]
                sl[dd] = 1
                dims_sorted = sorted(sl, key=lambda d: -sl[d])
        n_steps = 1
        for li in range(t + 1):
            n_steps *= _prod(per_slot[(li, False)].values())
        if n_steps > max_steps:
            continue
        do_stream = stream if stream is not None else (rng.random() < 0.5)
        blocks = _assemble_blocks(arch, per_slot, rng, stream=do_stream)
        m = Mapping(layer=layer, arch=arch, blocks=blocks)
        try:
            m.validate()
        except ValueError:
            continue
        return m
    # fall back to a deterministic valid mapping
    return heuristic_mapping(layer, arch)


def _prod(xs: Iterable[int]) -> int:
    p = 1
    for x in xs:
        p *= x
    return p


def _assemble_blocks(arch, per_slot, rng,
                     stream: bool = False) -> Tuple[Tuple[Loop, ...], ...]:
    t = arch.target_index
    blocks: List[Tuple[Loop, ...]] = []
    for li in range(len(arch.levels)):
        temporal = [Loop(d, f, False)
                    for d, f in per_slot[(li, False)].items() if f > 1]
        spatial = []
        if li < len(arch.levels) - 1:
            spatial = [Loop(d, f, True)
                       for d, f in per_slot[(li, True)].items() if f > 1]
        if stream:
            temporal = _stream_order(temporal, rng)
        else:
            rng.shuffle(temporal)
        rng.shuffle(spatial)
        if li == t:
            block = temporal + spatial  # temporal-before-spatial invariant
        else:
            block = temporal + spatial
            if not stream:
                rng.shuffle(block)
        blocks.append(tuple(block))
    return tuple(blocks)


_STREAM_GROUP = {"N": 0, "P": 0, "Q": 0, "K": 1, "C": 2, "R": 2, "S": 2}


def _stream_order(loops: List[Loop], rng) -> List[Loop]:
    """Overlap-friendly temporal order: spatial output position (P/Q)
    outermost, channels (K) next, reductions (C/R/S) innermost — each
    output region then completes (all channels, full reduction) early and
    in raster order, which is what gives the succeeding layer early ready
    times (paper Section III-C/D)."""
    rng.shuffle(loops)
    return sorted(loops, key=lambda lp: _STREAM_GROUP[lp.dim])


def heuristic_mapping(layer: LayerSpec, arch: ArchSpec,
                      max_steps: int = 65536) -> Mapping:
    """Deterministic output-stationary mapping: parallelize K/P/Q across
    banks, C/R/S across columns, remaining output dims temporal at bank."""
    t = arch.target_index
    n_levels = len(arch.levels)
    per_slot: Dict[Tuple[int, bool], Dict[str, int]] = {
        s: {d: 1 for d in DIMS} for s in _slot_order(arch)}

    rem = {d: layer.dim(d) for d in DIMS}
    # spatial across banks: split P then Q then K greedily
    for li in range(t):
        cap = arch.levels[li + 1].fanout
        used = 1
        for d in ("P", "Q", "K"):
            best = 1
            for f in divisors(rem[d]):
                if used * f <= cap:
                    best = max(best, f)
            per_slot[(li, True)][d] = best
            used *= best
            rem[d] //= best
    # spatial across columns at target: reduction dims then K
    cap = arch.levels[t + 1].fanout if t + 1 < n_levels else 1
    used = 1
    for d in ("C", "R", "S", "K"):
        best = 1
        for f in divisors(rem[d]):
            if used * f <= cap:
                best = max(best, f)
        per_slot[(t, True)][d] = best
        used *= best
        rem[d] //= best
    # everything else temporal at target level (bank steps), but keep the
    # step count bounded by pushing overflow into the innermost level.
    n_steps = _prod(rem.values())
    for d in ("C", "R", "S", "K", "Q", "P", "N"):
        while n_steps > max_steps and rem[d] > 1:
            small = min(f for f in divisors(rem[d]) if f > 1)
            per_slot[(n_levels - 1, False)][d] *= small
            rem[d] //= small
            n_steps //= small
    for d in DIMS:
        per_slot[(t, False)][d] = rem[d]

    blocks: List[Tuple[Loop, ...]] = []
    for li in range(n_levels):
        temporal = [Loop(d, f, False)
                    for d, f in per_slot[(li, False)].items() if f > 1]
        temporal.sort(key=lambda lp: _STREAM_GROUP[lp.dim])
        spatial = []
        if li < n_levels - 1:
            spatial = [Loop(d, f, True)
                       for d, f in per_slot[(li, True)].items() if f > 1]
        blocks.append(tuple(temporal + spatial))
    m = Mapping(layer=layer, arch=arch, blocks=tuple(blocks))
    m.validate()
    return m
