"""PIM performance model (paper Section IV-C, Table I).

Timeloop's model counts compute/read/write only; PIM needs the data
movements of in-memory execution. Each MAC in a bank is modeled as
(1) bit-serial element-wise multiplication, (2) read/write for operand
transposition, (3) serial additions for reduction. A full n-bit addition is
4n+1 activate-activate-precharge (AAP) operations; a multiplication is n
sequential additions (Section IV-C). Configured architectures may pin
add/mul latencies directly (Fig 6: DRAM add=196ns mul=980ns; Fig 7 ReRAM
add=442ns mul=696ns) — the AAP-derived model is the fallback.
"""
from __future__ import annotations

import dataclasses
import math

from .arch import ArchSpec
from .mapping import Mapping
from .workload import OUTPUT_DIMS, REDUCTION_DIMS


@dataclasses.dataclass(frozen=True)
class LayerPerf:
    """Latency/energy decomposition of one mapping (no overlap).

    ``energy_pj`` is the mapping-invariant base energy
    (``compute_energy_pj + io_energy_pj``); the mapping-*dependent*
    movement energy of transform-relocated tiles lives on the schedule
    result (``TransformResult.move_energy_pj`` / ``LayerResult``), fed by
    ``tile_bytes`` and ``move_pj_per_byte`` here (DESIGN.md Section 9).
    """

    step_ns: float          # latency of one bank time step
    n_steps: int
    n_banks: int
    compute_ns: float       # n_steps * step_ns
    output_move_ns: float   # write outputs to next layer's input region
    tile_move_ns: float     # movement of a single (bank, step) output tile
    sequential_ns: float    # compute + output movement
    energy_pj: float        # compute_energy_pj + io_energy_pj
    compute_energy_pj: float = 0.0  # bit-serial AAP MACs
    io_energy_pj: float = 0.0       # output write-out through the links
    tile_bytes: float = 0.0         # footprint of one (bank, step) tile
    move_pj_per_byte: float = 0.0   # link energy per relocated byte

    @property
    def total_ns(self) -> float:
        return self.sequential_ns


def step_latency_ns(mapping: Mapping) -> float:
    arch = mapping.arch
    t_add = arch.op_latency("add")
    t_mul = arch.op_latency("mul")
    timing = arch.timing

    macs_step = mapping.macs_per_step()
    cols = mapping.n_columns_used
    macs_per_col = math.ceil(macs_step / cols)

    # (1)+(3): bit-serial multiply + accumulate-add per MAC
    mac_ns = t_mul + t_add
    # (2): operand transposition — one row read + one row write per MAC
    t_rw = timing.t_rcd + timing.t_cl
    # cross-column partial-sum reduction (spatial reduction loops at target)
    n_red = 1
    out_cols = 1
    ti = arch.target_index
    for li, lp in mapping.nest:
        if li == ti and lp.spatial:
            if lp.dim in REDUCTION_DIMS:
                n_red *= lp.size
            else:
                out_cols *= lp.size
    red_ns = 0.0
    if n_red > 1:
        ext = mapping.tile_extent
        out_elems = 1
        for d in OUTPUT_DIMS:
            out_elems *= ext[d]
        out_per_col = math.ceil(out_elems / out_cols)
        move_word = arch.word_bytes * arch.movement_ns_per_byte()
        red_ns = math.ceil(math.log2(n_red)) * out_per_col * (
            move_word + t_add)
    return macs_per_col * (mac_ns + 2 * t_rw) + red_ns


def move_energy_pj(arch: ArchSpec, n_bytes: float) -> float:
    """Link energy of moving ``n_bytes`` between banks (pJ).

    Same per-bit IO energy the base model charges for inter-layer output
    movement (Table I ``e_io``), so transform-relocation energy and
    output-write energy are on one scale."""
    return n_bytes * 8 * arch.timing.e_io


def analyze(mapping: Mapping) -> LayerPerf:
    arch = mapping.arch
    layer = mapping.layer
    step_ns = step_latency_ns(mapping)
    n_steps = mapping.n_steps
    n_banks = mapping.n_banks
    compute_ns = step_ns * n_steps

    # inter-layer output->input data movement through channel links
    chan_level = arch.levels[min(1, len(arch.levels) - 1)]
    write_bw = chan_level.write_bw or 16.0
    channels_used = 1
    for li, lp in mapping.nest:
        if li == 0 and lp.spatial:
            channels_used *= lp.size
    out_bytes = layer.output_elems * arch.word_bytes
    output_move_ns = out_bytes / (write_bw * channels_used)

    ext = mapping.tile_extent
    tile_out = 1
    for d in OUTPUT_DIMS:
        tile_out *= ext[d]
    tile_move_ns = tile_out * arch.word_bytes / write_bw
    tile_bytes = tile_out * arch.word_bytes

    # energy: AAP-dominated bit-serial compute + IO for the movement
    n = arch.word_bits
    e_add = (4 * n + 1) * arch.timing.e_act
    e_mac = (n + 1) * e_add  # mul = n serial adds, + 1 accumulate add
    compute_energy = layer.macs * e_mac
    io_energy = out_bytes * 8 * arch.timing.e_io

    return LayerPerf(
        step_ns=step_ns, n_steps=n_steps, n_banks=n_banks,
        compute_ns=compute_ns, output_move_ns=output_move_ns,
        tile_move_ns=tile_move_ns,
        sequential_ns=compute_ns + output_move_ns,
        energy_pj=compute_energy + io_energy,
        compute_energy_pj=compute_energy, io_energy_pj=io_energy,
        tile_bytes=tile_bytes,
        move_pj_per_byte=move_energy_pj(arch, 1.0))


# ---------------------------------------------------------------------------
# Architecture cost proxies (DSE objectives; see repro.dse).
#
# Deliberately coarse: the DSE subsystem needs a consistent partial order
# over configurations, not sign-off-quality silicon numbers. Area counts the
# compute columns (the memory arrays doing bit-serial work), per-bank
# periphery (sense amps, row decoder, PIM control) and per-channel IO/TSV
# overhead. Power is peak: every bank running back-to-back AAPs (activation
# energy over the row-cycle time — faster timing bins burn more) plus the
# host-bus IO at full tilt.
# ---------------------------------------------------------------------------

_AREA_COL_MM2 = 1e-4     # one compute column (array slice)
_AREA_BANK_MM2 = 0.02    # bank periphery
_AREA_CHANNEL_MM2 = 0.5  # channel IO / TSV stack


def _channel_count(arch: ArchSpec) -> int:
    """Instances of the level just below the root (channels / tiles)."""
    return arch.instances_at(min(1, len(arch.levels) - 1))


def _physical_banks(arch: ArchSpec) -> int:
    """Instances of the level above compute (banks / blocks) — the
    *physical* structure, independent of where ``target_level`` puts the
    overlap analysis (identical hardware must cost identical area)."""
    return arch.instances_at(max(0, len(arch.levels) - 2))


def arch_area_proxy(arch: ArchSpec) -> float:
    """Relative die area (mm^2-ish) of a PIM configuration."""
    banks = _physical_banks(arch)
    cols = arch.instances_at(len(arch.levels) - 1)  # all compute columns
    return (cols * _AREA_COL_MM2 + banks * _AREA_BANK_MM2
            + _channel_count(arch) * _AREA_CHANNEL_MM2)


def arch_power_proxy(arch: ArchSpec) -> float:
    """Peak power (W-ish): all banks issuing AAPs continuously + IO.

    ``e_act / t_aap`` is pJ/ns = mW per continuously-activating bank, so a
    scaled-down (faster) timing raises power — the knob that keeps "just
    shrink the timing" from dominating the Pareto frontier for free."""
    t = arch.timing
    bank_mw = t.e_act / t.t_aap
    io_mw = arch.host_bus_gbps * 8 * t.e_io  # bytes/ns * bits * pJ/bit = mW
    return (_physical_banks(arch) * bank_mw + io_mw) / 1e3


class PerfCache:
    """Memoizes ``analyze()`` on ``(Mapping.cache_key, ArchSpec.to_key())``.

    ``Mapping.cache_key`` interns (layer, blocks) only, so the arch content
    key disambiguates equal nests under different architectures. Keying on
    content (not arch identity) lets one cache serve a multi-arch DSE sweep:
    revisiting an architecture — even via a distinct but equal ``ArchSpec``
    object — hits the existing entries."""

    def __init__(self):
        self._store: dict = {}
        #: plain-int hit/miss accounting (no telemetry dispatch — the
        #: engine folds these into its ``stats`` at publish time), so
        #: cross-request cache warming is observable (DESIGN.md §13)
        self.hits = 0
        self.misses = 0

    def analyze(self, mapping: Mapping) -> LayerPerf:
        key = (mapping.cache_key, mapping.arch.to_key())
        hit = self._store.get(key)
        if hit is None:
            self.misses += 1
            hit = self._store[key] = analyze(mapping)
        else:
            self.hits += 1
        return hit
