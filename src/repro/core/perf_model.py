"""PIM performance model (paper Section IV-C, Table I).

Timeloop's model counts compute/read/write only; PIM needs the data
movements of in-memory execution. Each MAC in a bank is modeled as
(1) bit-serial element-wise multiplication, (2) read/write for operand
transposition, (3) serial additions for reduction. A full n-bit addition is
4n+1 activate-activate-precharge (AAP) operations; a multiplication is n
sequential additions (Section IV-C). Configured architectures may pin
add/mul latencies directly (Fig 6: DRAM add=196ns mul=980ns; Fig 7 ReRAM
add=442ns mul=696ns) — the AAP-derived model is the fallback.
"""
from __future__ import annotations

import dataclasses
import math

from .arch import ArchSpec
from .mapping import Mapping
from .workload import OUTPUT_DIMS, REDUCTION_DIMS


@dataclasses.dataclass(frozen=True)
class LayerPerf:
    """Latency/energy decomposition of one mapping (no overlap)."""

    step_ns: float          # latency of one bank time step
    n_steps: int
    n_banks: int
    compute_ns: float       # n_steps * step_ns
    output_move_ns: float   # write outputs to next layer's input region
    tile_move_ns: float     # movement of a single (bank, step) output tile
    sequential_ns: float    # compute + output movement
    energy_pj: float

    @property
    def total_ns(self) -> float:
        return self.sequential_ns


def step_latency_ns(mapping: Mapping) -> float:
    arch = mapping.arch
    t_add = arch.op_latency("add")
    t_mul = arch.op_latency("mul")
    timing = arch.timing

    macs_step = mapping.macs_per_step()
    cols = mapping.n_columns_used
    macs_per_col = math.ceil(macs_step / cols)

    # (1)+(3): bit-serial multiply + accumulate-add per MAC
    mac_ns = t_mul + t_add
    # (2): operand transposition — one row read + one row write per MAC
    t_rw = timing.t_rcd + timing.t_cl
    # cross-column partial-sum reduction (spatial reduction loops at target)
    n_red = 1
    out_cols = 1
    ti = arch.target_index
    for li, lp in mapping.nest:
        if li == ti and lp.spatial:
            if lp.dim in REDUCTION_DIMS:
                n_red *= lp.size
            else:
                out_cols *= lp.size
    red_ns = 0.0
    if n_red > 1:
        ext = mapping.tile_extent
        out_elems = 1
        for d in OUTPUT_DIMS:
            out_elems *= ext[d]
        out_per_col = math.ceil(out_elems / out_cols)
        move_word = arch.word_bytes * arch.movement_ns_per_byte()
        red_ns = math.ceil(math.log2(n_red)) * out_per_col * (
            move_word + t_add)
    return macs_per_col * (mac_ns + 2 * t_rw) + red_ns


def analyze(mapping: Mapping) -> LayerPerf:
    arch = mapping.arch
    layer = mapping.layer
    step_ns = step_latency_ns(mapping)
    n_steps = mapping.n_steps
    n_banks = mapping.n_banks
    compute_ns = step_ns * n_steps

    # inter-layer output->input data movement through channel links
    chan_level = arch.levels[min(1, len(arch.levels) - 1)]
    write_bw = chan_level.write_bw or 16.0
    channels_used = 1
    for li, lp in mapping.nest:
        if li == 0 and lp.spatial:
            channels_used *= lp.size
    out_bytes = layer.output_elems * arch.word_bytes
    output_move_ns = out_bytes / (write_bw * channels_used)

    ext = mapping.tile_extent
    tile_out = 1
    for d in OUTPUT_DIMS:
        tile_out *= ext[d]
    tile_move_ns = tile_out * arch.word_bytes / write_bw

    # energy: AAP-dominated bit-serial compute + IO for the movement
    n = arch.word_bits
    e_add = (4 * n + 1) * arch.timing.e_act
    e_mac = (n + 1) * e_add  # mul = n serial adds, + 1 accumulate add
    energy = layer.macs * e_mac + out_bytes * 8 * arch.timing.e_io

    return LayerPerf(
        step_ns=step_ns, n_steps=n_steps, n_banks=n_banks,
        compute_ns=compute_ns, output_move_ns=output_move_ns,
        tile_move_ns=tile_move_ns,
        sequential_ns=compute_ns + output_move_ns, energy_pj=energy)


class PerfCache:
    """Memoizes ``analyze()`` on ``Mapping.cache_key`` (layer + blocks).

    ``ArchSpec`` is not hashable (per-level op dicts), so entries pin the
    arch instance and are invalidated when a mapping with the same content
    key arrives under a different arch object. One instance per search run
    (the batched engine owns one)."""

    def __init__(self):
        self._store: dict = {}

    def analyze(self, mapping: Mapping) -> LayerPerf:
        key = mapping.cache_key
        hit = self._store.get(key)
        if hit is None or hit[0] is not mapping.arch:
            hit = (mapping.arch, analyze(mapping))
            self._store[key] = hit
        return hit[1]
