"""Overlap-driven mapping transformation (paper Section IV-I).

Given the per-space input-ready times of an analyzed mapping, re-sort data
spaces in ascending ready order and re-allocate them round-robin across the
layer's bank instances. This turns any analyzed mapping into an
overlap-friendly one in O(N log N) (bounded by the sort) without
re-analyzing data spaces. The transformation is not free: spaces that move
to a different bank require their partial inputs to be moved, charged as
``tile_move_ns`` on the relocated space's ready time — and, energy-wise,
as ``tile_bytes`` of data pushed through the channel links per relocated
space (``moved_bytes`` / ``move_energy_pj`` on the result; the paper
charges relocation in time only, the energy accounting is the
ROADMAP's "energy-aware transform search" extension).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TransformResult:
    end_ns: float
    finish_ns: np.ndarray   # (nb, nt), indexed by ORIGINAL (bank, step) ids
    moved_frac: float       # fraction of spaces re-homed to another bank
    moved_bytes: float = 0.0     # data relocated across banks
    move_energy_pj: float = 0.0  # moved_bytes * move_pj_per_byte


def transform_schedule(ready_ns: np.ndarray, step_ns: float,
                       tile_move_ns: float = 0.0,
                       start_floor: float = 0.0,
                       order: np.ndarray = None,
                       tile_bytes=0.0,
                       move_pj_per_byte: float = 0.0) -> TransformResult:
    """``order``, when given, must equal ``np.argsort(flat, kind='stable')``
    of the flattened ready times — the batched engine precomputes it with
    an integer radix sort on producer finish-time ranks (same ordering,
    ~5x cheaper than the float mergesort).

    ``tile_bytes`` is the data footprint relocated per re-homed space:
    a scalar (uniform tiles, the common case) or an array broadcastable
    to ``ready_ns.shape`` indexed by ORIGINAL (bank, step) ids. It feeds
    only the ``moved_bytes`` / ``move_energy_pj`` accounting — the
    schedule itself (``end_ns`` / ``finish_ns`` / ``moved_frac``) is
    unchanged for any value, so callers that ignore energy keep the exact
    pre-existing behavior.
    """
    nb, nt = ready_ns.shape
    flat = ready_ns.reshape(-1)
    if order is None:
        order = np.argsort(flat, kind="stable")      # ascending ready time
    n = flat.size

    pos = np.arange(n, dtype=np.int64)
    new_bank = pos % nb                              # round-robin allocation
    slot = pos // nb
    orig_bank = order // nt
    moved = new_bank != orig_bank
    eff_ready = np.maximum(flat[order] + moved * tile_move_ns, start_floor)

    # per-bank closed-form schedule: spaces of bank b are positions b::nb,
    # already in ascending ready order.
    fin_sorted = np.empty(n, dtype=np.float64)
    nslots = (n + nb - 1) // nb
    # pad to rectangular (nb, nslots) for vectorization
    pad = nslots * nb - n
    r = np.concatenate([eff_ready, np.full(pad, -np.inf)])
    r = r.reshape(nslots, nb).T                      # (nb, nslots)
    s = np.arange(nslots, dtype=np.float64)
    base = np.maximum.accumulate(r - s[None, :] * step_ns, axis=1)
    fin = base + (s[None, :] + 1) * step_ns          # (nb, nslots)
    fin_flat = fin.T.reshape(-1)[:n]
    fin_sorted[:] = fin_flat

    out = np.empty(n, dtype=np.float64)
    out[order] = fin_sorted
    valid_end = float(fin_flat.max()) if n else 0.0

    n_moved = int(moved.sum())
    if np.ndim(tile_bytes) == 0:
        moved_bytes = n_moved * float(tile_bytes)
    else:
        tb = np.broadcast_to(
            np.asarray(tile_bytes, dtype=np.float64), (nb, nt)).reshape(-1)
        moved_bytes = float(tb[order[moved]].sum())
    return TransformResult(end_ns=valid_end,
                           finish_ns=out.reshape(nb, nt),
                           moved_frac=float(moved.mean()) if n else 0.0,
                           moved_bytes=moved_bytes,
                           move_energy_pj=moved_bytes * move_pj_per_byte)


def transform_end_grouped(values: np.ndarray, counts: np.ndarray,
                          n_steps: np.ndarray, step_ns: np.ndarray,
                          tile_move_ns: np.ndarray,
                          start_floor: float = 0.0):
    """Closed-form ``transform_schedule`` end time + moved-space count for a
    batch of candidates whose ready matrices are given as grouped
    (value, original-bank) histograms instead of dense (nb, nt) arrays.

    ``values`` is (K, V) float64: each candidate's distinct ready values in
    strictly ascending order (rows right-padded arbitrarily — padded slots
    must carry zero counts). ``counts`` is (K, V, nb) int64:
    ``counts[k, v, b]`` spaces of candidate ``k`` with original bank ``b``
    share ready value ``values[k, v]``. All candidates in one call share
    ``nb``; ``n_steps`` / ``step_ns`` / ``tile_move_ns`` are (K,) arrays.
    Returns ``(end_ns, n_moved)`` as (K,) arrays.

    Exactness (DESIGN.md Section 6): the stable ascending sort of the dense
    matrix orders spaces by (value, flat index), and flat index order
    within one value group is original-bank-major — so the histogram
    determines the exact sorted sequence. Under round-robin re-allocation
    position ``p`` lands in bank ``p % nb`` at slot ``p // nb`` and is
    *unmoved* iff ``p % nb`` equals its original bank. Every space of a
    (value, bank) run shares ``eff = max(value [+ tile_move if moved],
    floor)``; within a run each per-new-bank term ``eff - slot * L`` is
    maximal at the run's first unmoved / first moved position (slot is
    nondecreasing along the run and float ``a - b`` / ``t * L`` are
    monotone), so the global schedule maximum — and hence
    ``end = max(eff - slot * L) + n_steps * L`` — needs only two
    representatives per run. Bit-identical to ``transform_schedule``
    (differential-tested)."""
    K, V, nb = counts.shape
    nt = np.asarray(n_steps, dtype=np.int64)
    L = np.asarray(step_ns, dtype=np.float64)[:, None, None]
    tmv = np.asarray(tile_move_ns, dtype=np.float64)[:, None, None]
    gsize = counts.sum(axis=2)                      # (K, V)
    gstart = np.cumsum(gsize, axis=1) - gsize       # exclusive prefix
    off = np.cumsum(counts, axis=2) - counts        # within-group offsets
    s = gstart[:, :, None] + off                    # run starts (K, V, nb)
    e = s + counts
    b = np.arange(nb, dtype=np.int64)[None, None, :]
    nonempty = counts > 0
    # unmoved spaces of run [s, e): positions p with p % nb == b
    unmoved = np.where(nonempty, (e - b - 1) // nb - (s - b - 1) // nb, 0)
    n_moved = nb * nt - unmoved.sum(axis=(1, 2))
    fu = s + ((b - s) % nb)                         # first unmoved position
    has_u = nonempty & (fu < e)
    fm = np.where(s % nb != b, s, s + 1)            # first moved position
    has_m = nonempty & (fm < e) & (nb > 1)
    vv = np.asarray(values, dtype=np.float64)[:, :, None]
    effu = np.maximum(vv, start_floor)
    effm = np.maximum(vv + tmv, start_floor)
    xu = np.where(has_u, effu - (fu // nb).astype(np.float64) * L, -np.inf)
    xm = np.where(has_m, effm - (fm // nb).astype(np.float64) * L, -np.inf)
    best = np.maximum(xu, xm).max(axis=(1, 2))
    end = best + nt.astype(np.float64) * np.asarray(step_ns,
                                                    dtype=np.float64)
    return end, n_moved
