"""Whole-network mapping search (paper Sections IV-J/K, V-B).

Modes (the paper's comparison points, Section V-A2):
  * ``original``  — Timeloop-style: best sequential latency, no overlap.
  * ``overlap``   — search on overlapped latency (no transformation).
  * ``transform`` — search on transformed overlapped latency
                    (= Fast-OverlaPIM's "Best Transform").

Strategies (Section IV-K): ``forward``, ``backward``, ``middle_output``
(start at the layer with the largest P*Q*K), ``middle_overall`` (largest
P*Q*C*K). Per layer the mapper samples a fixed number of valid candidate
mappings (termination criterion "similar to Timeloop": a fixed number of
valid mappings) and the succeeding/preceding layer is optimized against the
fixed choice — the linear method of Section IV-J (k*N instead of k^N).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from .arch import ArchSpec
from .mapping import Mapping, heuristic_mapping, random_mapping
from .overlap import (Edge, overlapped_end, ready_steps_analytical,
                      ready_steps_exhaustive, schedule_with_ready,
                      stream_tail_fraction)
from .perf_model import LayerPerf, analyze
from .transform import transform_schedule
from .workload import LayerSpec

MODES = ("original", "overlap", "transform")
STRATEGIES = ("forward", "backward", "middle_output", "middle_overall")
# energy-aware objectives (DESIGN.md Section 9): "latency" is the paper's
# objective; "energy" minimizes base + transform-movement energy; "edp" the
# energy-delay product; "blend" a weighted geometric mean of the two.
OBJECTIVES = ("latency", "energy", "edp", "blend")


def combine_objective(objective: str, latency_ns: float, energy_pj: float,
                      blend_alpha: float = 0.5) -> float:
    """Scalarize one (latency, energy) pair under a named objective.

    Used identically for candidate scores and whole-network refine
    comparisons, on both the engine and reference paths — any asymmetry
    would break the engine's bit-identity contract. ``blend`` is the
    weighted geometric mean ``latency^(1-a) * energy^a`` (scale-free, so
    the ns/pJ unit mismatch cannot silently weight one term)."""
    if objective == "latency":
        return latency_ns
    if objective == "energy":
        return energy_pj
    if objective == "edp":
        return latency_ns * energy_pj
    if objective == "blend":
        a = blend_alpha
        return latency_ns ** (1.0 - a) * energy_pj ** a
    raise ValueError(f"unknown objective {objective!r}")


@dataclasses.dataclass
class SearchConfig:
    n_candidates: int = 48
    seed: int = 0
    max_steps: int = 16384
    mode: str = "transform"
    strategy: str = "forward"
    use_exhaustive_overlap: bool = False  # OverlaPIM's analysis (slow)
    # beyond-paper: coordinate-descent passes re-optimizing each layer
    # against both committed neighbors (0 = the paper's linear search)
    refine_passes: int = 0
    refine_candidates: int = 8
    # batched/memoizing engine (core.engine); False = per-candidate
    # reference path, kept as the differential-test oracle
    use_engine: bool = True
    # scoring objective ("latency" reproduces the paper exactly);
    # blend_alpha is the energy weight of the "blend" objective
    objective: str = "latency"
    blend_alpha: float = 0.5

    def __post_init__(self):
        assert self.mode in MODES, self.mode
        assert self.strategy in STRATEGIES, self.strategy
        assert self.objective in OBJECTIVES, self.objective
        assert 0.0 <= self.blend_alpha <= 1.0, self.blend_alpha


@dataclasses.dataclass
class LayerResult:
    mapping: Mapping
    perf: LayerPerf
    start_ns: float
    end_ns: float
    finish_ns: np.ndarray          # (nb, nt) absolute space finish times
    transformed: bool = False
    moved_frac: float = 0.0
    moved_bytes: float = 0.0       # data relocated by the transformation
    move_energy_pj: float = 0.0

    @property
    def latency_ns(self) -> float:
        return self.end_ns - self.start_ns

    @property
    def energy_pj(self) -> float:
        """Full layer energy: mapping-invariant base + movement."""
        return self.perf.energy_pj + self.move_energy_pj


@dataclasses.dataclass
class NetworkResult:
    layers: List[LayerResult]
    total_ns: float
    mode: str
    per_layer_ns: List[float] = dataclasses.field(default_factory=list)
    objective: str = "latency"     # objective the search optimized

    @property
    def total_energy_pj(self) -> float:
        return sum(l.energy_pj for l in self.layers)

    def objective_value(self, objective: Optional[str] = None,
                        blend_alpha: float = 0.5) -> float:
        """The network-level scalar the refine loop compares."""
        return combine_objective(objective or self.objective,
                                 self.total_ns, self.total_energy_pj,
                                 blend_alpha)

    def summary(self) -> Dict[str, float]:
        compute = sum(l.perf.compute_energy_pj for l in self.layers)
        io = sum(l.perf.io_energy_pj for l in self.layers)
        move = sum(l.move_energy_pj for l in self.layers)
        energy = self.total_energy_pj
        return {"total_ns": self.total_ns,
                "n_layers": len(self.layers),
                "mode": self.mode,
                "objective": self.objective,
                "energy_pj": energy,
                "compute_energy_pj": compute,
                "io_energy_pj": io,
                "move_energy_pj": move,
                "moved_bytes": sum(l.moved_bytes for l in self.layers),
                "edp_ns_pj": self.total_ns * energy}


# ---------------------------------------------------------------------------
# Chain evaluation for a FIXED set of mappings.
# ---------------------------------------------------------------------------

def _ready_matrix(idx: int, mapping: Mapping, edges: Sequence[Edge],
                  done: Dict[int, LayerResult],
                  use_exhaustive: bool = False) -> np.ndarray:
    """Absolute ready time per (bank, step) of ``mapping``, max over
    dependency edges (paper Section IV-G: latest producing space).

    ``use_exhaustive`` switches the ready-step analysis to OverlaPIM's
    O(N*M) traversal (``SearchConfig.use_exhaustive_overlap``) — the
    baseline the paper compares against. Result-identical to the
    analytical path (property-tested), just slow."""
    nb, nt = mapping.n_banks, mapping.n_steps
    ready = np.zeros((nb, nt), dtype=np.float64)
    ready_steps = (ready_steps_exhaustive if use_exhaustive
                   else ready_steps_analytical)
    for e in edges:
        prod = done[e.producer]
        step, ready0 = ready_steps(prod.mapping, mapping, e.cmap)
        # synchronous-time-step semantics (paper Fig 3): a step completes
        # when all banks complete it
        fin_step = prod.finish_ns.max(axis=0)
        r = fin_step[step] + prod.perf.tile_move_ns
        r = np.where(ready0, 0.0, r)
        ready = np.maximum(ready, r)
    return ready


def evaluate_chain(mappings: Sequence[Mapping],
                   edges: Sequence[Sequence[Edge]],
                   mode: str,
                   use_exhaustive_overlap: bool = False) -> NetworkResult:
    """Run the whole network with fixed mappings under a given mode."""
    done: Dict[int, LayerResult] = {}
    per_layer = []
    for i, m in enumerate(mappings):
        perf = analyze(m)
        nb, nt = m.n_banks, m.n_steps
        if mode == "original":
            start = max((done[e.producer].end_ns for e in edges[i]),
                        default=0.0)
            t = np.arange(nt, dtype=np.float64)
            fin = start + np.broadcast_to(
                (t + 1) * perf.step_ns, (nb, nt)).copy()
            end = start + perf.compute_ns + perf.output_move_ns
            res = LayerResult(m, perf, start, end, fin)
        else:
            ready = _ready_matrix(i, m, edges[i], done,
                                  use_exhaustive_overlap)
            start = float(ready.min()) if ready.size else 0.0
            if mode == "transform" and edges[i]:
                tr = transform_schedule(
                    ready, perf.step_ns, perf.tile_move_ns,
                    tile_bytes=perf.tile_bytes,
                    move_pj_per_byte=perf.move_pj_per_byte)
                fin = tr.finish_ns
                end = tr.end_ns + perf.output_move_ns
                res = LayerResult(m, perf, start, end, fin,
                                  transformed=True,
                                  moved_frac=tr.moved_frac,
                                  moved_bytes=tr.moved_bytes,
                                  move_energy_pj=tr.move_energy_pj)
            else:
                fin = schedule_with_ready(ready, perf.step_ns)
                end = float(fin[:, -1].max()) + perf.output_move_ns
                res = LayerResult(m, perf, start, end, fin)
        done[i] = res
        per_layer.append(res.latency_ns)
    total = max(r.end_ns for r in done.values()) if done else 0.0
    return NetworkResult(layers=[done[i] for i in range(len(mappings))],
                         total_ns=total, mode=mode, per_layer_ns=per_layer)


# ---------------------------------------------------------------------------
# Per-layer candidate generation + greedy linear search.
# ---------------------------------------------------------------------------

def candidates(layer: LayerSpec, arch: ArchSpec,
               cfg: SearchConfig, salt: int) -> List[Mapping]:
    rng = random.Random((cfg.seed << 20) ^ salt)
    out = [heuristic_mapping(layer, arch, cfg.max_steps)]
    seen = {out[0].blocks}
    for _ in range(cfg.n_candidates - 1):
        m = random_mapping(layer, arch, rng, cfg.max_steps)
        if m.blocks not in seen:
            seen.add(m.blocks)
            out.append(m)
    return out


def _score_forward(i, m, edges, done, mode, has_consumer=True,
                   objective="latency", blend_alpha=0.5,
                   use_exhaustive=False) -> float:
    perf = analyze(m)
    if mode == "original":
        base = max((done[e.producer].end_ns for e in edges[i]), default=0.0)
        return combine_objective(objective, base + perf.sequential_ns,
                                 perf.energy_pj, blend_alpha)
    # successor-friendliness: penalize production orders whose outputs all
    # complete at the end (they deny the next layer any overlap)
    tail = stream_tail_fraction(m) if has_consumer else 0.0
    penalty = tail * perf.compute_ns
    if not edges[i]:
        return combine_objective(objective, perf.sequential_ns + penalty,
                                 perf.energy_pj, blend_alpha)
    ready = _ready_matrix(i, m, edges[i], done, use_exhaustive)
    if mode == "transform":
        tr = transform_schedule(ready, perf.step_ns, perf.tile_move_ns,
                                tile_bytes=perf.tile_bytes,
                                move_pj_per_byte=perf.move_pj_per_byte)
        return combine_objective(
            objective, tr.end_ns + perf.output_move_ns + penalty,
            perf.energy_pj + tr.move_energy_pj, blend_alpha)
    return combine_objective(
        objective,
        overlapped_end(ready, perf.step_ns) + perf.output_move_ns + penalty,
        perf.energy_pj, blend_alpha)


def _commit(i, m, edges, done, mode, use_exhaustive=False) -> LayerResult:
    perf = analyze(m)
    nb, nt = m.n_banks, m.n_steps
    if mode == "original" or not edges[i]:
        start = max((done[e.producer].end_ns for e in edges[i]),
                    default=0.0) if mode == "original" else 0.0
        t = np.arange(nt, dtype=np.float64)
        fin = start + np.broadcast_to((t + 1) * perf.step_ns,
                                      (nb, nt)).copy()
        end = start + perf.compute_ns + perf.output_move_ns
        return LayerResult(m, perf, start, end, fin)
    ready = _ready_matrix(i, m, edges[i], done, use_exhaustive)
    start = float(ready.min())
    if mode == "transform":
        tr = transform_schedule(ready, perf.step_ns, perf.tile_move_ns,
                                tile_bytes=perf.tile_bytes,
                                move_pj_per_byte=perf.move_pj_per_byte)
        return LayerResult(m, perf, start, tr.end_ns + perf.output_move_ns,
                           tr.finish_ns, transformed=True,
                           moved_frac=tr.moved_frac,
                           moved_bytes=tr.moved_bytes,
                           move_energy_pj=tr.move_energy_pj)
    fin = schedule_with_ready(ready, perf.step_ns)
    return LayerResult(m, perf, start,
                       float(fin[:, -1].max()) + perf.output_move_ns, fin)


def _consumers_of(edges: Sequence[Sequence[Edge]], i: int) -> List[int]:
    return [j for j, es in enumerate(edges)
            if any(e.producer == i for e in es)]


def _score_backward(i, m, edges, fixed: Dict[int, Mapping], mode,
                    objective="latency", blend_alpha=0.5,
                    use_exhaustive=False) -> float:
    """Score a producer candidate by the end time (scalarized under the
    objective) of its (fixed-mapping) consumers, assuming the producer
    starts stall-free at t=0."""
    perf = analyze(m)
    done = {i: LayerResult(
        m, perf, 0.0, perf.sequential_ns,
        np.broadcast_to((np.arange(m.n_steps) + 1.0) * perf.step_ns,
                        (m.n_banks, m.n_steps)).copy())}
    cons = [j for j in _consumers_of(edges, i) if j in fixed]
    if mode == "original" or not cons:
        return combine_objective(objective, perf.sequential_ns,
                                 perf.energy_pj, blend_alpha)
    worst = 0.0
    for j in cons:
        mc = fixed[j]
        pc = analyze(mc)
        es = [e for e in edges[j] if e.producer == i]
        ready = _ready_matrix(j, mc, es, done, use_exhaustive)
        if mode == "transform":
            tr = transform_schedule(ready, pc.step_ns, pc.tile_move_ns,
                                    tile_bytes=pc.tile_bytes,
                                    move_pj_per_byte=pc.move_pj_per_byte)
            sc = combine_objective(objective, tr.end_ns,
                                   pc.energy_pj + tr.move_energy_pj,
                                   blend_alpha)
        else:
            sc = combine_objective(objective,
                                   overlapped_end(ready, pc.step_ns),
                                   pc.energy_pj, blend_alpha)
        worst = max(worst, sc)
    return worst


def optimize_network(layers: Sequence[LayerSpec],
                     edges: Sequence[Sequence[Edge]],
                     arch: ArchSpec,
                     cfg: Optional[SearchConfig] = None) -> NetworkResult:
    cfg = cfg or SearchConfig()
    with obs.span("search.optimize", n_layers=len(layers), mode=cfg.mode,
                  strategy=cfg.strategy, objective=cfg.objective,
                  engine=cfg.use_engine
                  and not cfg.use_exhaustive_overlap):
        # the OverlaPIM-baseline analysis has no batched engine twin:
        # fall back to the reference path (the engine itself raises if
        # handed the flag directly)
        if cfg.use_engine and not cfg.use_exhaustive_overlap:
            from .engine import optimize_network_engine  # lazy: no cycle
            return optimize_network_engine(layers, edges, arch, cfg)
        return _optimize_network_reference(layers, edges, arch, cfg)


def _optimize_network_reference(layers: Sequence[LayerSpec],
                                edges: Sequence[Sequence[Edge]],
                                arch: ArchSpec,
                                cfg: SearchConfig) -> NetworkResult:
    """Pre-engine per-candidate path — the differential-test oracle."""
    n = len(layers)
    order, backward_part = _visit_order(layers, cfg.strategy)
    exh = cfg.use_exhaustive_overlap

    chosen: Dict[int, Mapping] = {}
    done: Dict[int, LayerResult] = {}
    for i in order:
        cands = candidates(layers[i], arch, cfg, salt=i)
        if i in backward_part:
            best = min(cands,
                       key=lambda m: _score_backward(i, m, edges, chosen,
                                                     cfg.mode,
                                                     cfg.objective,
                                                     cfg.blend_alpha,
                                                     exh))
        else:
            # forward scoring needs producers committed; producers missing
            # (backward half not yet visited) fall back to sequential score
            avail = all(e.producer in done for e in edges[i])
            has_cons = bool(_consumers_of(edges, i))
            if avail:
                best = min(cands, key=lambda m: _score_forward(
                    i, m, edges, done, cfg.mode, has_cons,
                    cfg.objective, cfg.blend_alpha, exh))
            else:
                def _seq_score(m):
                    p = analyze(m)
                    return combine_objective(cfg.objective,
                                             p.sequential_ns, p.energy_pj,
                                             cfg.blend_alpha)
                best = min(cands, key=_seq_score)
        chosen[i] = best
        if all(e.producer in done for e in edges[i]):
            done[i] = _commit(i, best, edges, done, cfg.mode, exh)
    result = evaluate_chain([chosen[i] for i in range(n)], edges,
                            cfg.mode, exh)
    # coordinate-descent refinement (beyond-paper): re-optimize each layer
    # against BOTH its committed producer and consumer — the paper's
    # linear pass is myopic about successors (Section IV-K motivates this)
    for _ in range(cfg.refine_passes if cfg.mode != "original" else 0):
        improved = False
        for i in range(n):
            rcfg = dataclasses.replace(
                cfg, n_candidates=cfg.refine_candidates)
            cands = candidates(layers[i], arch, rcfg, salt=i + 7919)
            cands.append(chosen[i])
            best_m = chosen[i]
            best_t = result.objective_value(cfg.objective, cfg.blend_alpha)
            for m in cands:
                trial = chosen.copy()
                trial[i] = m
                r = evaluate_chain([trial[j] for j in range(n)], edges,
                                   cfg.mode, exh)
                sc = r.objective_value(cfg.objective, cfg.blend_alpha)
                if sc < best_t - 1e-9:
                    best_m, best_t = m, sc
            if best_m is not chosen[i]:
                chosen[i] = best_m
                improved = True
        result = evaluate_chain([chosen[i] for i in range(n)], edges,
                                cfg.mode, exh)
        if not improved:
            break
    result.objective = cfg.objective
    return result


def _visit_order(layers: Sequence[LayerSpec],
                 strategy: str) -> Tuple[List[int], set]:
    n = len(layers)
    if strategy == "forward":
        return list(range(n)), set()
    if strategy == "backward":
        return list(range(n - 1, -1, -1)), set(range(n - 1))
    key = ((lambda l: l.output_size()) if strategy == "middle_output"
           else (lambda l: l.overall_size()))
    mid = max(range(n), key=lambda i: key(layers[i]))
    order = [mid] + list(range(mid - 1, -1, -1)) + list(range(mid + 1, n))
    return order, set(range(mid))
